"""Recompute the analytic roofline terms in results/dryrun/*.json with the
current cost model (compile evidence is untouched — only t_* / bytes
fields are refreshed)."""
import glob
import json
import sys

sys.path.insert(0, "src")

from repro.configs import get_config  # noqa: E402
from repro.core.planner.cost_model import roofline_terms  # noqa: E402

MESHES = {"single": {"data": 16, "model": 16},
          "pod": {"pod": 2, "data": 16, "model": 16}}

for f in glob.glob("results/dryrun/*.json"):
    # results/perf/*.json are hillclimb records produced with their own
    # meshes/flags — never rewrite them with default-mesh analytics
    rec = json.load(open(f))
    if rec.get("status") != "ok":
        continue
    cfg = get_config(rec["arch"])
    if "ssm_chunk" in f or "chunk" in f:
        import dataclasses
        cfg = dataclasses.replace(cfg, ssm_chunk=512)
    mesh = MESHES.get(rec["mesh"])
    if mesh is None:  # hillclimb custom mesh, e.g. 32x8 — parse from chips
        continue
    kss = "kvseqshard" in f
    rt = roofline_terms(cfg, rec["shape"], mesh, kv_seq_shard=kss)
    rec.update(flops=rt["flops"],
               hbm_bytes_per_chip=rt["hbm_bytes_per_chip"],
               collective_bytes_per_chip=rt["collective_bytes_per_chip"],
               t_compute=rt["t_compute"], t_memory=rt["t_memory"],
               t_collective=rt["t_collective"],
               bottleneck=rt["bottleneck"])
    rec["useful_flops_ratio"] = rec["model_flops"] / max(rt["flops"], 1.0)
    json.dump(rec, open(f, "w"), indent=1)
print("refreshed")
