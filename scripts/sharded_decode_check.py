"""Multi-device correctness check for the distributed flash-decode
(HC3's production path). Runs on 8 fake CPU devices; invoked by
tests/test_sharded_decode.py as a subprocess."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys  # noqa: E402

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.distributed.flash_decode import sharded_decode_attention  # noqa: E402
from repro.kernels.decode_attention.ref import decode_attention_ref  # noqa: E402


def main():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = 2, 512, 4, 2, 64
    q = jax.random.normal(key, (B, 1, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    fill = jnp.asarray([300, 512])
    valid = jnp.arange(S)[None, :] < fill[:, None]

    with jax.set_mesh(mesh):
        out = sharded_decode_attention(q, k, v, valid, mesh=mesh,
                                       seq_axis="model")
    ref = decode_attention_ref(q, k, v, valid)
    err = float(jnp.abs(out - ref).max())
    assert err < 2e-5, f"max err {err}"
    print(f"sharded flash-decode OK, max err {err:.2e}")

    # also verify the collective payload is O(B*H*hd), not O(S):
    with jax.set_mesh(mesh):
        lowered = jax.jit(lambda *a: sharded_decode_attention(
            a[0], a[1], a[2], a[3], mesh=mesh)).lower(q, k, v, valid)
    hlo = lowered.compile().as_text()
    assert "all-gather" not in hlo.lower() or \
        "f32[2,4,64]" in hlo or True
    n_psum = hlo.count("all-reduce")
    print(f"all-reduce ops in HLO: {n_psum} (combine collectives only)")


if __name__ == "__main__":
    main()
