"""Multi-device check: explicit all_to_all EP MoE == single-device MoE
oracle (drop-free shapes). 8 fake CPU devices (2 data x 4 model)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys  # noqa: E402

sys.path.insert(0, "src")

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.distributed.expert_parallel import ep_moe_ffn  # noqa: E402
from repro.models import moe as moe_mod  # noqa: E402


def main():
    cfg = dataclasses.replace(
        get_config("deepseek_v2_236b").reduced(),
        num_experts=8, top_k=2, moe_d_ff=32, d_model=64,
        num_shared_experts=0)
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, cfg)
    # scale weights so outputs are O(1) — a zero-output pass is vacuous
    p = jax.tree.map(lambda a: a * 10.0, p)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, 64))

    # oracle: generous capacity => no drops
    y_ref, _ = moe_mod.moe_ffn(p, x, cfg, capacity_factor=8.0)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with jax.set_mesh(mesh):
        y = ep_moe_ffn(p, x, cfg, mesh=mesh, capacity_factor=8.0)
    err = float(jnp.abs(jnp.asarray(y) - jnp.asarray(y_ref)).max())
    scale = float(jnp.abs(y_ref).max())
    assert scale > 0.5, f"vacuous comparison (scale {scale})"
    assert err < 2e-2 * scale, f"max err {err} (scale {scale})"
    print(f"EP MoE all_to_all OK, max err {err:.2e} (output scale {scale:.2f})")

    with jax.set_mesh(mesh):
        hlo = jax.jit(lambda pp, xx: ep_moe_ffn(pp, xx, cfg, mesh=mesh)) \
            .lower(p, x).compile().as_text()
    n_a2a = hlo.count("all-to-all")
    assert n_a2a >= 2, "expected explicit all-to-all dispatch + return"
    print(f"all-to-all ops in HLO: {n_a2a}")


if __name__ == "__main__":
    main()
