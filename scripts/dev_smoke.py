"""Dev-only quick smoke over all reduced configs (forward + decode)."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import (decode_step, forward, init_cache, init_params,
                          count_params)

only = sys.argv[1:] or ARCH_IDS
for arch in only:
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = 2, 16
    batch = {"tokens": jnp.zeros((B, S), jnp.int32)}
    if cfg.arch_type == "audio":
        batch["frames"] = jnp.ones((B, cfg.encoder_frames, cfg.d_model), jnp.float32)
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = jnp.ones((B, cfg.vision_tokens, cfg.d_model))
    logits, aux = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"
    # decode one token
    cache = init_cache(cfg, B, 32)
    tok = jnp.zeros((B,), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    lg, cache = jax.jit(lambda p, c, t, q: decode_step(p, cfg, c, t, q))(
        params, cache, tok, pos)
    assert lg.shape == (B, cfg.vocab_size), (arch, lg.shape)
    assert not bool(jnp.isnan(lg).any()), f"{arch}: NaN decode"
    print(f"OK {arch:22s} params={count_params(params):,} "
          f"logits={tuple(logits.shape)}")
print("ALL OK")
