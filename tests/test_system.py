"""End-to-end system behaviour: the full AsyncFlow stack (TransferQueue +
async workflow + real JAX engines + GRPO) on a tiny model, plus the
service API and a subprocess dry-run."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import AsyncFlowService, Trainer, TrainerConfig


def _fit(mode, steps=3):
    tcfg = TrainerConfig(arch="qwen2_5_7b", mode=mode, num_steps=steps,
                         prompts_per_step=2, group_size=2,
                         rollout_workers=2, rollout_batch=1,
                         train_micro_batch=2, max_new_tokens=4, seq_len=24)
    return Trainer(tcfg).fit()


def test_end_to_end_async_grpo():
    r = _fit("async")
    assert r.samples_trained == 3 * 4
    assert len(r.metrics) == 3                 # one optimizer step per step
    assert max(r.staleness_seen) <= 2
    for m in r.metrics:
        assert np.isfinite(m["loss"])
        assert np.isfinite(m["grad_norm"])


def test_end_to_end_baseline_on_policy():
    r = _fit("baseline")
    assert max(r.staleness_seen) == 0
    assert len(r.metrics) == 3


def test_service_api_roundtrip():
    svc = AsyncFlowService()
    svc.create_queue("exp", capacity=8,
                     tasks={"actor_update": ["prompt", "reward"]})
    svc.put_prompts_data("exp", ["p0", "p1", "p2"])
    svc.put_experience_data(
        "exp", {"prompt": ["x"] * 2, "reward": [1.0, 0.0]})
    # rows with both columns present are consumable
    got = svc.get_experience_data("exp", "actor_update", 2, timeout=1.0)
    assert got is not None and len(got["reward"]) == 2
    # weight sync notify bumps versions
    v1 = svc.weight_sync_notify({"w": np.zeros(2)})
    v2 = svc.weight_sync_notify({"w": np.ones(2)})
    assert v2 == v1 + 1
    recv = svc.register_receiver({"w": np.zeros(2)})
    svc.sender.flush()
    assert recv.wait_and_swap(v2, timeout=2.0)
    assert float(recv.params["w"][0]) == 1.0


def test_dryrun_subprocess_whisper_single():
    """One real dry-run lowering through the CLI (512 fake devices)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper_tiny", "--shape", "decode_32k", "--mesh", "single"],
        capture_output=True, text=True, timeout=900, env=env, cwd=root)
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.loads(r.stdout)
    assert rec["status"] == "ok"
    assert rec["n_chips"] == 256
    assert rec["bottleneck"] in ("compute", "memory", "collective")


def test_end_to_end_grpo_with_kl_reference():
    """Three-task dataflow: rollout + reference inference + actor update,
    all streaming through TransferQueue; KL penalty is finite and the
    ref_logprob column reaches the trainer."""
    tcfg = TrainerConfig(arch="qwen2_5_7b", mode="async", num_steps=2,
                         prompts_per_step=2, group_size=2,
                         rollout_workers=1, rollout_batch=2,
                         train_micro_batch=2, max_new_tokens=4,
                         seq_len=24, kl_coef=0.05)
    r = Trainer(tcfg).fit()
    assert len(r.metrics) == 2
    for m in r.metrics:
        assert np.isfinite(m["loss"])


def test_trainer_checkpoint_roundtrip(tmp_path):
    ckpt = str(tmp_path / "rl_ckpt")
    tcfg = TrainerConfig(arch="qwen2_5_7b", mode="streaming", num_steps=1,
                         prompts_per_step=2, group_size=2,
                         rollout_workers=1, rollout_batch=2,
                         train_micro_batch=4, max_new_tokens=4,
                         seq_len=24, checkpoint_dir=ckpt)
    t = Trainer(tcfg)
    t.fit()
    # a fresh trainer restores the state and continues
    t2 = Trainer(TrainerConfig(arch="qwen2_5_7b", num_steps=1,
                               prompts_per_step=2, group_size=2,
                               rollout_workers=1, rollout_batch=2,
                               train_micro_batch=4, max_new_tokens=4,
                               seq_len=24))
    # the run-snapshot machinery owns the checkpoint_dir root; the
    # legacy single-state dump lands in "<dir>/final"
    step = t2.restore(str(tmp_path / "rl_ckpt" / "final"))
    assert step == 1
    import jax
    for a, b in zip(jax.tree.leaves(t.train_engine.state.params),
                    jax.tree.leaves(t2.train_engine.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
