"""Registry + parameter-count fidelity for the assigned architectures."""
import pytest

from repro.configs import ARCH_IDS, all_configs, get_config

# expected total parameter counts (from the source papers / model cards)
EXPECTED_PARAMS = {
    "recurrentgemma_9b": 9e9,
    "stablelm_12b": 12e9,
    "minicpm3_4b": 4e9,
    "grok_1_314b": 314e9,
    "whisper_tiny": 39e6,
    "minicpm_2b": 2.7e9,
    "qwen1_5_32b": 32e9,
    "falcon_mamba_7b": 7e9,
    "deepseek_v2_236b": 236e9,
    "internvl2_26b": 20e9,   # LM backbone only (InternLM2-20B); ViT stubbed
    "qwen2_5_7b": 7.6e9,
    "qwen2_5_32b": 32e9,
}


def test_registry_complete():
    cfgs = all_configs()
    assert len(cfgs) == 12
    for arch in ARCH_IDS:
        assert cfgs[arch].name


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_counts_within_tolerance(arch):
    cfg = get_config(arch)
    got = cfg.param_count()
    want = EXPECTED_PARAMS[arch]
    assert 0.6 * want <= got <= 1.45 * want, \
        f"{arch}: {got/1e9:.2f}B vs expected {want/1e9:.2f}B"


def test_moe_active_params():
    g = get_config("grok_1_314b")
    assert g.active_param_count() < g.param_count() * 0.45
    d = get_config("deepseek_v2_236b")
    # DeepSeek-V2: ~21B active of 236B
    assert d.active_param_count() < d.param_count() * 0.2


def test_reduced_configs_small():
    for arch in ARCH_IDS:
        r = get_config(arch).reduced()
        assert r.num_layers == 2
        assert r.d_model <= 512
        assert r.num_experts in (0, 4)


def test_aliases():
    assert get_config("qwen1.5-32b").name == "qwen1.5-32b"
    with pytest.raises(KeyError):
        get_config("nonexistent-13b")
