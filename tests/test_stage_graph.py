"""Streaming stage-graph subsystem: topology validation, per-stage
pipeline overlap, fused-vs-staged GRPO equivalence, PPO through the
graph in all three workflow modes, and custom stage registration."""
import dataclasses
import time
from types import SimpleNamespace

import jax
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.api import AsyncFlowService, Trainer, TrainerConfig
from repro.core.obs import MetricsRegistry
from repro.core.workflow import (AsyncRLRunner, StageGraph, StageRunner,
                                 StageSpec, WorkflowConfig, build_dataflow)
from repro.data import PromptDataset
from repro.engines import JaxRolloutEngine, JaxTrainEngine
from repro.models import init_params
from repro.rl.grpo import GRPOConfig
from repro.training.optimizer import OptimizerConfig


# ---------------------------------------------------------------------- #
# topology validation                                                     #
# ---------------------------------------------------------------------- #

def test_graph_missing_producer_rejected():
    g = StageGraph(source_columns=("prompt",))
    g.add(StageSpec("a", inputs=("prompt", "nope"), outputs=("x",)))
    with pytest.raises(ValueError, match="no producer"):
        g.validate()


def test_graph_duplicate_producer_rejected():
    g = StageGraph(source_columns=("prompt",))
    g.add(StageSpec("a", inputs=("prompt",), outputs=("x",)))
    g.add(StageSpec("b", inputs=("prompt",), outputs=("x",)))
    with pytest.raises(ValueError, match="produced by both"):
        g.validate()


def test_graph_cycle_rejected():
    g = StageGraph(source_columns=())
    g.add(StageSpec("a", inputs=("y",), outputs=("x",)))
    g.add(StageSpec("b", inputs=("x",), outputs=("y",)))
    with pytest.raises(ValueError, match="cycle"):
        g.validate()


def test_graph_self_loop_rejected():
    g = StageGraph(source_columns=())
    g.add(StageSpec("a", inputs=("x",), outputs=("x",)))
    with pytest.raises(ValueError, match="own output|cycle"):
        g.validate()


def test_graph_topo_order():
    g = build_dataflow("ppo", kl_coef=0.1)
    order = [s.name for s in g.topo_order()]
    assert order.index("generate") < order.index("values")
    assert order.index("values") < order.index("advantage")
    assert order.index("reward") < order.index("advantage")
    assert order.index("advantage") < order.index("actor_update")
    assert order.index("ref_inference") < order.index("actor_update")


def test_unknown_dataflow():
    with pytest.raises(KeyError, match="unknown dataflow"):
        build_dataflow("definitely_not_registered")


# ---------------------------------------------------------------------- #
# generic StageRunner (no JAX): a 3-stage toy dataflow streams and        #
# overlaps per stage                                                      #
# ---------------------------------------------------------------------- #

def _toy_graph():
    def gen(batch, *, params, rng, version=0, **kw):
        time.sleep(0.01)
        return {"rows": [dict(item=x, token_len=1)
                         for x in batch["prompt"] for _ in range(2)]}

    def enrich(batch, *, indices, **kw):
        time.sleep(0.004)
        return {"updates": {"score": [v + 1 for v in batch["item"]]}}

    def train(batch, **kw):
        time.sleep(0.002)
        assert all(s == v + 1 for v, s in zip(batch["item"],
                                              batch["score"]))
        return {"n": len(batch["version"])}

    g = StageGraph(source_columns=("prompt",))
    g.add(StageSpec("generate", inputs=("prompt",),
                    outputs=("item", "version"), engine="", fn=gen,
                    kind="generate"))
    g.add(StageSpec("enrich", inputs=("item",), outputs=("score",),
                    fn=enrich))
    g.add(StageSpec("actor_update", inputs=("item", "score", "version"),
                    engine="trainer", fn=train, kind="train",
                    drives_steps=True))
    return g


def test_stage_runner_toy_dataflow_streams_per_stage():
    cfg = WorkflowConfig(mode="streaming", num_rollout_workers=2,
                         rollout_batch=2, train_micro_batch=4,
                         prompts_per_step=4, group_size=2, num_steps=3)
    runner = StageRunner(
        cfg, _toy_graph(),
        engines={"trainer": SimpleNamespace(params={"w": 0})},
        prompt_stream=lambda s: [1, 2, 3, 4])
    r = runner.run()
    assert r.samples_trained == 3 * 8
    assert max(r.staleness_seen) == 0          # streaming is on-policy
    kinds = {e.kind for e in r.log.events()}
    assert "enrich" in kinds and "generate" in kinds and "update" in kinds
    # pipeline overlap: the intermediate stage starts before the last
    # generation finishes (no global-batch barrier between stages)
    enrich_ev = [e for e in r.log.events() if e.kind == "enrich"]
    gen_ev = [e for e in r.log.events() if e.kind == "generate"]
    assert min(e.start for e in enrich_ev) < max(e.end for e in gen_ev)


def test_stage_runner_auto_sizes_zero_worker_stages():
    """auto_size_workers=True planner-sizes every stage left at
    num_workers=0 and the run still trains the exact sample count."""
    cfg = WorkflowConfig(mode="streaming", num_rollout_workers=2,
                         rollout_batch=2, train_micro_batch=4,
                         prompts_per_step=4, group_size=2, num_steps=3,
                         auto_size_workers=True, max_stage_workers=4)
    runner = StageRunner(
        cfg, _toy_graph(),
        engines={"trainer": SimpleNamespace(params={"w": 0})},
        prompt_stream=lambda s: [1, 2, 3, 4], metrics=MetricsRegistry())
    assert set(runner.stage_costs) == {"generate", "enrich", "actor_update"}
    assert runner._desired["actor_update"] == 1
    assert all(1 <= n <= 4 for n in runner._desired.values())
    r = runner.run()
    assert r.samples_trained == 3 * 8
    snap = {tuple(sorted(row["labels"].items())): row["value"]
            for row in runner.registry.get("stage_workers").snapshot()}
    assert snap[(("stage", "actor_update"),)] == 1


def test_stage_runner_elastic_grows_starved_generate_pool():
    """Live rebalance: a single slow generate worker starves the driver,
    the elastic monitor grows the pool mid-run, and the run completes."""
    def slow_gen(batch, *, params, rng, version=0, **kw):
        time.sleep(0.05)
        return {"rows": [dict(item=x, token_len=1)
                         for x in batch["prompt"] for _ in range(2)]}

    g = _toy_graph()
    g.stages["generate"] = dataclasses.replace(g.stages["generate"],
                                               fn=slow_gen)
    cfg = WorkflowConfig(mode="streaming", num_rollout_workers=1,
                         rollout_batch=1, train_micro_batch=4,
                         prompts_per_step=4, group_size=2, num_steps=10,
                         elastic_interval_s=0.1, max_stage_workers=4)
    runner = StageRunner(
        cfg, g, engines={"trainer": SimpleNamespace(params={"w": 0})},
        prompt_stream=lambda s: [1, 2, 3, 4], metrics=MetricsRegistry())
    r = runner.run()
    assert r.samples_trained == 10 * 8
    reb = runner.registry.get("stage_rebalance_total")
    assert reb is not None
    # the starved driver made the monitor grow the generate pool mid-run
    # (it may shrink again once the prompt stream drains at the tail)
    assert reb.value(stage="generate", action="grow") >= 1
    assert runner.registry.get("stage_workers").value(stage="generate") >= 1


def test_stage_runner_requires_generate_and_driver():
    g = StageGraph(source_columns=("prompt",))
    g.add(StageSpec("a", inputs=("prompt",), outputs=("x",)))
    cfg = WorkflowConfig(num_steps=1)
    with pytest.raises(ValueError, match="generate stage"):
        StageRunner(cfg, g, engines={}, prompt_stream=lambda s: [])


# ---------------------------------------------------------------------- #
# GRPO: staged graph reproduces the fused (pre-refactor) pipeline on a    #
# fixed seed                                                              #
# ---------------------------------------------------------------------- #

def test_grpo_staged_matches_fused_fixed_seed():
    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    common = dict(mode="baseline", num_steps=3, prompts_per_step=2,
                  group_size=2, train_micro_batch=4)
    # deterministic schedule: one worker, whole-step generate batches,
    # one storage unit (atomic batch availability)
    opt = OptimizerConfig(lr=3e-4, warmup_steps=2, total_steps=3,
                          schedule=cfg.lr_schedule
                          if cfg.lr_schedule != "cosine" else "constant")
    fused_train = JaxTrainEngine(cfg, params, rl=GRPOConfig(), opt=opt,
                                 global_batch=4, seq_len=24)
    fused = AsyncRLRunner(
        WorkflowConfig(num_rollout_workers=1, rollout_batch=2,
                       num_storage_units=1, **common),
        rollout_engine=JaxRolloutEngine(cfg, group_size=2,
                                        max_new_tokens=4),
        train_engine=fused_train,
        prompt_stream=lambda s: PromptDataset(seed=0).prompts_for_step(s, 2))
    r_fused = fused.run()

    tcfg = TrainerConfig(num_steps=3, prompts_per_step=2, group_size=2,
                         rollout_workers=1, rollout_batch=2,
                         train_micro_batch=4, max_new_tokens=4, seq_len=24,
                         mode="baseline", num_storage_units=1, seed=0)
    r_staged = Trainer(tcfg, model_cfg=cfg, params=params).fit()

    assert len(r_fused.metrics) == len(r_staged.metrics) == 3
    for mf, ms in zip(r_fused.metrics, r_staged.metrics):
        assert mf["step"] == ms["step"]
        for k in ("loss", "policy_loss", "grad_norm", "mean_reward"):
            np.testing.assert_allclose(mf[k], ms[k], rtol=1e-4, atol=1e-5,
                                       err_msg=k)


# ---------------------------------------------------------------------- #
# GRPO + KL through the graph: ref_inference and reward stream as         #
# distinct overlapping stages                                             #
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("mode", ["baseline", "streaming", "async"])
def test_grpo_kl_stages_stream_and_overlap(mode):
    tcfg = TrainerConfig(mode=mode, num_steps=2, prompts_per_step=4,
                         group_size=2, rollout_workers=2, rollout_batch=2,
                         train_micro_batch=4, max_new_tokens=4, seq_len=24,
                         kl_coef=0.05)
    r = Trainer(tcfg).fit()
    assert len(r.metrics) == 2
    assert all(np.isfinite(m["loss"]) for m in r.metrics)
    assert max(r.staleness_seen) <= (2 if mode == "async" else 0)
    ev = r.log.events()
    ref_ev = [e for e in ev if e.kind == "ref_inference"]
    rew_ev = [e for e in ev if e.kind == "reward"]
    gen_ev = [e for e in ev if e.kind == "generate"]
    assert ref_ev and rew_ev, "ref_inference/reward must be own stages"
    # streaming overlap: intermediate stages start while generation for
    # later rows is still running — no stage waits for the global batch
    assert min(e.start for e in ref_ev) < max(e.end for e in gen_ev)
    assert min(e.start for e in rew_ev) < max(e.end for e in gen_ev)
    # and the bubble accounting sees the new stages as busy time
    bf = r.log.bubble_fraction()
    assert any(k.startswith("ref_inference") for k in bf)
    assert any(k.startswith("reward") for k in bf)


# ---------------------------------------------------------------------- #
# PPO end-to-end through the graph in all three workflow modes            #
# ---------------------------------------------------------------------- #

def test_ppo_all_modes_through_stage_graph():
    for mode in ("baseline", "streaming", "async"):
        tcfg = TrainerConfig(algorithm="ppo", mode=mode, num_steps=2,
                             prompts_per_step=2, group_size=2,
                             rollout_workers=2, rollout_batch=1,
                             train_micro_batch=2, max_new_tokens=4,
                             seq_len=24)
        r = Trainer(tcfg).fit()
        assert r.samples_trained == 2 * 4, mode
        assert len(r.metrics) == 2, mode       # one actor step per step
        assert all(np.isfinite(m["loss"]) for m in r.metrics), mode
        critic = r.aux_metrics.get("critic_update", [])
        assert critic and all(np.isfinite(m["value_loss"]) for m in critic)
        kinds = {e.kind for e in r.log.events()}
        assert {"values", "advantage", "critic_update"} <= kinds, mode
        if mode == "baseline":
            assert max(r.staleness_seen) == 0
        if mode == "async":
            assert max(r.staleness_seen) <= 2


# ---------------------------------------------------------------------- #
# §5.1 service APIs: registering a custom stage onto a built-in dataflow  #
# ---------------------------------------------------------------------- #

def test_service_custom_stage_registration():
    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    svc = AsyncFlowService()
    graph = svc.build_dataflow("grpo", kl_coef=0.0)

    def seq_stats(batch, *, indices, **kw):
        return {"updates": {"resp_len":
                            [int(np.asarray(m).sum())
                             for m in batch["response_mask"]]}}

    svc.register_stage(graph, StageSpec(
        "seq_stats", inputs=("response_mask",), outputs=("resp_len",),
        fn=seq_stats))
    graph.validate()

    wcfg = WorkflowConfig(mode="streaming", num_rollout_workers=1,
                          rollout_batch=2, train_micro_batch=4,
                          prompts_per_step=2, group_size=2, num_steps=1)
    engines = {
        "rollout": JaxRolloutEngine(cfg, group_size=2, max_new_tokens=4),
        "actor": JaxTrainEngine(cfg, params, global_batch=4, seq_len=24)}
    r = svc.run_dataflow(graph, wcfg,
                         lambda s: PromptDataset(seed=0).prompts_for_step(
                             s, 2),
                         engines=engines)
    assert r.samples_trained == 4
    assert any(e.kind == "seq_stats" for e in r.log.events())


def test_service_register_custom_dataflow():
    svc = AsyncFlowService()
    svc.register_dataflow("toy", lambda **kw: _toy_graph())
    g = svc.build_dataflow("toy")
    assert set(g.stages) == {"generate", "enrich", "actor_update"}
