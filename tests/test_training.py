"""Optimizer, schedules, checkpointing, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.data import ByteTokenizer, MathDataset, PromptDataset
from repro.training import (OptimizerConfig, TrainState, adamw_update,
                            clip_by_global_norm, init_opt_state,
                            make_schedule, restore_checkpoint,
                            save_checkpoint)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = OptimizerConfig(lr=0.1, weight_decay=0.0, grad_clip=100.0,
                          warmup_steps=1)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert norm == pytest.approx(5.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, abs=1e-5)
    same, _ = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(same["a"], g["a"])


def test_wsd_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, schedule="wsd", warmup_steps=10,
                          total_steps=100, stable_frac=0.6, min_lr_frac=0.1)
    s = make_schedule(cfg)
    assert float(s(0)) < 0.2            # warmup
    assert float(s(30)) == pytest.approx(1.0)   # stable plateau
    assert float(s(59)) == pytest.approx(1.0)
    assert float(s(99)) < 0.25          # decayed
    assert float(s(99)) >= 0.1 - 1e-6   # floor


def test_cosine_schedule_monotone_after_warmup():
    cfg = OptimizerConfig(lr=1.0, schedule="cosine", warmup_steps=5,
                          total_steps=50)
    s = make_schedule(cfg)
    vals = [float(s(t)) for t in range(5, 50, 5)]
    assert all(a >= b - 1e-6 for a, b in zip(vals, vals[1:]))


def test_checkpoint_roundtrip(tmp_path, tiny_dense_params):
    state = TrainState.create(tiny_dense_params)
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, state, step=7)
    restored, step = restore_checkpoint(path, state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_structure_mismatch(tmp_path):
    save_checkpoint(str(tmp_path / "c"), {"a": jnp.zeros(2)})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path / "c"), {"b": jnp.zeros(2)})


def test_checkpoint_torn_write_leaves_old_intact(tmp_path, monkeypatch):
    """A save that dies mid-write (disk full / SIGKILL before the rename)
    must leave the previous checkpoint untouched and no debris behind."""
    path = str(tmp_path / "c")
    save_checkpoint(path, {"a": jnp.arange(4.0)}, step=1)

    def _boom(*args, **kwargs):
        raise OSError("disk full mid-write")

    monkeypatch.setattr(np, "savez", _boom)
    with pytest.raises(OSError):
        save_checkpoint(path, {"a": jnp.zeros(4)}, step=2)
    monkeypatch.undo()
    # the failed attempt cleaned its temp dir and never touched the target
    assert os.listdir(tmp_path) == ["c"]
    restored, step = restore_checkpoint(path, {"a": jnp.zeros(4)})
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(4.0))


def test_checkpoint_treedef_order_mismatch_names_leaf_and_step(tmp_path):
    """Same leaf names in a different treedef order (a refactor reordered
    NamedTuple fields) is the nastiest mismatch — silently loading would
    swap arrays. The error must say so and name the saved step."""
    from typing import Any, NamedTuple

    class AB(NamedTuple):
        a: Any
        b: Any

    class BA(NamedTuple):
        b: Any
        a: Any

    save_checkpoint(str(tmp_path / "c"), AB(jnp.zeros(2), jnp.ones(3)),
                    step=5)
    with pytest.raises(ValueError) as ei:
        restore_checkpoint(str(tmp_path / "c"), BA(jnp.ones(3), jnp.zeros(2)))
    msg = str(ei.value)
    assert "different treedef order" in msg
    assert "saved at step 5" in msg


# -- data -------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.text(max_size=40))
def test_tokenizer_roundtrip(text):
    tok = ByteTokenizer()
    ids = tok.encode(text, add_bos=True, add_eos=True)
    assert tok.decode(ids) == text


def test_pad_batch_left_right():
    tok = ByteTokenizer()
    seqs = [tok.encode("ab"), tok.encode("abcd")]
    toks, mask = tok.pad_batch(seqs)
    assert toks.shape == mask.shape == (2, 5)
    assert mask[0].sum() == 3  # bos + 2 bytes
    ltoks, lmask = tok.pad_batch(seqs, left=True)
    assert lmask[0, :2].sum() == 0


def test_dataset_answers_correct():
    ds = MathDataset(seed=0)
    for s in ds.batch(50):
        a, rest = s.prompt[0], s.prompt[1:]
        expr = s.prompt[:-1]
        assert eval(expr) == s.answer  # arithmetic ground truth


def test_prompt_stream_deterministic():
    ds = PromptDataset(seed=0)
    a = ds.prompts_for_step(3, 4)
    b = ds.prompts_for_step(3, 4)
    assert [x["text"] for x in a] == [x["text"] for x in b]
    c = ds.prompts_for_step(4, 4)
    assert [x["text"] for x in a] != [x["text"] for x in c]
