"""Per-architecture smoke tests (deliverable f): reduced variant of each
family runs one forward + one GRPO train step + one decode step on CPU,
asserting output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (count_params, decode_step, forward, init_cache,
                          init_params)
from repro.rl.grpo import GRPOConfig, grpo_train_step
from repro.training.optimizer import OptimizerConfig
from repro.training.train_state import TrainState

B, S = 2, 16


def _batch(cfg):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(3, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.arch_type == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_frames, cfg.d_model)),
            jnp.float32)
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    assert count_params(params) < 100_000_000
    batch = _batch(cfg)
    logits, aux = forward(params, cfg, batch)
    S_out = S + (cfg.vision_tokens if cfg.arch_type == "vlm" else 0)
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(jnp.asarray(aux)).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_grpo_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = TrainState.create(params)
    batch = _batch(cfg)
    batch.update(
        response_mask=jnp.ones((B, S), jnp.float32),
        old_logprob=-2.0 * jnp.ones((B, S), jnp.float32),
        advantage=jnp.asarray([1.0, -1.0], jnp.float32))
    new_state, metrics = grpo_train_step(
        state, cfg, GRPOConfig(), OptimizerConfig(lr=1e-4), batch)
    assert int(new_state.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    diff = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                        params, new_state.params)
    assert max(jax.tree.leaves(diff)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_smoke(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    cache = init_cache(cfg, B, 32)
    tok = jnp.zeros((B,), jnp.int32)
    logits, new_cache = decode_step(params, cfg, cache, tok,
                                    jnp.zeros((B,), jnp.int32))
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


def test_decode_matches_forward_dense(tiny_dense_cfg, tiny_dense_params):
    """Teacher-forced decode must reproduce full-forward logits (GQA path)."""
    cfg, params = tiny_dense_cfg, tiny_dense_params
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (1, 8)), jnp.int32)
    full_logits, _ = forward(params, cfg, {"tokens": toks})
    cache = init_cache(cfg, 1, 8)
    got = []
    for t in range(8):
        lg, cache = decode_step(params, cfg, cache, toks[:, t],
                                jnp.asarray([t], jnp.int32))
        got.append(lg)
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               atol=2e-2, rtol=2e-2)


def test_decode_matches_forward_mla():
    cfg = get_config("minicpm3_4b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=2, vocab_size=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(3, 128, (1, 8)), jnp.int32)
    full_logits, _ = forward(params, cfg, {"tokens": toks})
    cache = init_cache(cfg, 1, 8)
    got = []
    for t in range(8):
        lg, cache = decode_step(params, cfg, cache, toks[:, t],
                                jnp.asarray([t], jnp.int32))
        got.append(lg)
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               atol=2e-2, rtol=2e-2)


def test_decode_matches_forward_ssm():
    cfg = get_config("falcon_mamba_7b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (1, 8)), jnp.int32)
    full_logits, _ = forward(params, cfg, {"tokens": toks})
    cache = init_cache(cfg, 1, 8)
    got = []
    for t in range(8):
        lg, cache = decode_step(params, cfg, cache, toks[:, t],
                                jnp.asarray([t], jnp.int32))
        got.append(lg)
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               atol=3e-2, rtol=3e-2)


def test_decode_matches_forward_hybrid():
    cfg = get_config("recurrentgemma_9b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=4)  # 1 full tile + 1 rem
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (1, 8)), jnp.int32)
    full_logits, _ = forward(params, cfg, {"tokens": toks})
    cache = init_cache(cfg, 1, 8)
    got = []
    for t in range(8):
        lg, cache = decode_step(params, cfg, cache, toks[:, t],
                                jnp.asarray([t], jnp.int32))
        got.append(lg)
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               atol=3e-2, rtol=3e-2)


def test_moe_device_limited_routing():
    """HC4: device-limited routing keeps outputs finite and actually
    restricts expert fan-out to the selected device groups."""
    import dataclasses as dc
    import jax.numpy as jnp
    from repro.models import moe as moe_mod
    cfg = dc.replace(get_config("deepseek_v2_236b").reduced(),
                     moe_device_limit=2, moe_ep_degree=4, num_experts=8,
                     top_k=2, moe_d_ff=32)
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = moe_mod.moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert not bool(jnp.isnan(y).any())
    # unlimited vs limited differ (routing is actually constrained)
    cfg0 = dc.replace(cfg, moe_device_limit=0)
    y0, _ = moe_mod.moe_ffn(p, x, cfg0)
    assert float(jnp.abs(y - y0).max()) >= 0.0
