"""TransferQueue: scheduling semantics + concurrency + hypothesis
properties (no duplication, exactly-once consumption)."""
import threading
import time

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.transfer_queue import (DataPlane, StorageUnit,
                                       TransferQueue,
                                       TransferQueueController)


def test_storage_unit_ownership():
    u = StorageUnit(1, 4)
    assert u.owns(5) and not u.owns(4)
    with pytest.raises(ValueError):
        u.put(4, "c", 0)


def test_data_plane_striping_and_order():
    dp = DataPlane(num_units=3)
    idxs = [0, 1, 2, 3, 4, 5, 7]
    dp.put_batch(idxs, "x", [f"v{i}" for i in idxs])
    got = dp.get([7, 0, 5], ["x"])
    assert got["x"] == ["v7", "v0", "v5"]


def test_data_plane_cross_unit_gather_order():
    """Gather preserves request order even when consecutive indices live
    on different storage units and are requested shuffled/reversed."""
    dp = DataPlane(num_units=4)
    idxs = list(range(13))
    dp.put_batch(idxs, "x", [f"v{i}" for i in idxs])
    dp.put_batch(idxs, "y", [i * 10 for i in idxs])
    req = [12, 3, 7, 0, 9, 1, 11, 2]   # spans all four units, shuffled
    got = dp.get(req, ["x", "y"])
    assert got["x"] == [f"v{i}" for i in req]
    assert got["y"] == [i * 10 for i in req]


def test_storage_unit_get_missing_raises_named_keyerror():
    u = StorageUnit(0, 1)
    u.put(0, "a", "v")
    with pytest.raises(KeyError, match=r"row 0.*column 'b'"):
        u.get([0], ["b"])                      # missing column
    with pytest.raises(KeyError, match=r"row 3.*column 'a'"):
        u.get([3], ["a"])                      # missing row
    dp = DataPlane(num_units=2)
    dp.put(1, "a", "v")
    with pytest.raises(KeyError, match=r"row 1.*column 'zz'"):
        dp.get([1], ["zz"])


def test_request_wait_excludes_scheduling_time():
    """total_wait_s measures only the blocked interval (§3.5): a request
    served from already-available rows accrues ~zero wait even when
    token_balance packing runs."""
    c = TransferQueueController("t", ["x"], capacity=512,
                                policy="token_balance")
    for i in range(512):
        c.set_token_len(i, i % 97)
        c.notify(i, "x")
    c.request(256, consumer="dpA")
    assert c.n_requests == 1
    assert c.total_wait_s < 0.05

    # a genuinely blocked request does accrue wait
    c2 = TransferQueueController("t2", ["x"], capacity=4)

    def feed():
        time.sleep(0.08)
        c2.notify(0, "x")

    th = threading.Thread(target=feed)
    th.start()
    meta = c2.request(1, timeout=5.0)
    th.join()
    assert meta is not None
    assert c2.total_wait_s >= 0.05


def test_controller_requires_all_columns():
    c = TransferQueueController("t", ["a", "b"], capacity=4)
    c.notify(0, "a")
    assert c.num_ready() == 0
    c.notify(0, "b")
    assert c.num_ready() == 1


def test_controller_ignores_unknown_columns_and_overflow():
    c = TransferQueueController("t", ["a"], capacity=2)
    c.notify(0, "zzz")
    c.notify(99, "a")
    assert c.num_ready() == 0


def test_exactly_once_consumption():
    tq = TransferQueue(capacity=10, tasks={"t": ["x"]})
    idxs = tq.next_indices(10)
    tq.put_batch(idxs, "x", list(range(10)))
    a = tq.get("t", 6)
    b = tq.get("t", 4)
    assert sorted(a["indices"] + b["indices"]) == idxs
    tq.close()
    assert tq.get("t", 1, timeout=0.05) is None


def test_streaming_dataloader_drains_then_stops():
    tq = TransferQueue(capacity=7, tasks={"t": ["x"]})
    idxs = tq.next_indices(7)
    tq.put_batch(idxs, "x", list(range(7)))
    tq.close_task("t")
    seen = []
    for batch, ix in tq.dataloader("t", 3):
        seen.extend(ix)
    assert sorted(seen) == idxs  # partial final batch delivered


def test_token_balance_policy():
    tq = TransferQueue(capacity=8, tasks={"t": ["x"]}, policy="token_balance")
    idxs = tq.next_indices(8)
    lens = [1, 100, 2, 90, 3, 80, 4, 70]
    tq.put_batch(idxs, "x", list(range(8)), token_lens=lens)
    a = tq.get("t", 4, consumer="dpA")
    b = tq.get("t", 4, consumer="dpB")
    tok = {i: l for i, l in zip(idxs, lens)}
    ta = sum(tok[i] for i in a["indices"])
    tb = sum(tok[i] for i in b["indices"])
    total = sum(lens)
    # balanced within 40% (fifo would give 193 vs 157 at best, worst 350/0)
    assert abs(ta - tb) <= 0.4 * total


def test_per_task_policy_and_decision_labels():
    """policy may be {task: name}: every consumer stage can token-balance
    independently, and tq_sched_decisions_total records the policy each
    micro-batch was *actually* packed with (token_balance falls back to
    fifo until token hints exist)."""
    from repro.core.obs import MetricsRegistry
    m = MetricsRegistry()
    tq = TransferQueue(capacity=8, tasks={"bal": ["x"], "plain": ["x"]},
                       policy={"bal": "token_balance"}, metrics=m)
    assert tq.controllers["bal"].policy == "token_balance"
    assert tq.controllers["plain"].policy == "fifo"

    # before any token hints: token_balance controller packs fifo
    idxs = tq.next_indices(4)
    tq.put_batch(idxs, "x", list(range(4)))
    tq.get("bal", 2)
    sched = m.get("tq_sched_decisions_total")
    assert sched.value(task="bal", policy="fifo") == 1

    # with hints the non-legacy stage balances tokens across consumers
    idxs2 = tq.next_indices(4)
    lens = [1, 100, 2, 90]
    tq.put_batch(idxs2, "x", list(range(4)), token_lens=lens)
    a = tq.get("bal", 3, consumer="dpA")
    assert sched.value(task="bal", policy="token_balance") == 1
    tq.get("plain", 4, consumer="dpB")
    assert sched.value(task="plain", policy="fifo") == 1
    tok = dict(zip(idxs2, lens))
    assert any(tok.get(i, 0) >= 90 for i in a["indices"])  # long/short mix


def test_blocking_consumer_wakes_on_write():
    tq = TransferQueue(capacity=2, tasks={"t": ["x"]})
    out = {}

    def consume():
        out["batch"] = tq.get("t", 2, timeout=5.0)

    th = threading.Thread(target=consume)
    th.start()
    time.sleep(0.05)
    idxs = tq.next_indices(2)
    tq.put_batch(idxs, "x", ["a", "b"])
    th.join(timeout=5.0)
    assert out["batch"]["x"] == ["a", "b"]


@settings(max_examples=25, deadline=None)
@given(n_rows=st.integers(1, 40), n_units=st.integers(1, 5),
       batch=st.integers(1, 7), n_consumers=st.integers(1, 4))
def test_property_no_duplication_no_loss(n_rows, n_units, batch, n_consumers):
    """Whatever the storage-unit count / batch size / consumer count,
    every row is consumed exactly once."""
    tq = TransferQueue(capacity=n_rows, tasks={"t": ["x"]},
                       num_storage_units=n_units)
    idxs = tq.next_indices(n_rows)
    tq.put_batch(idxs, "x", list(range(n_rows)))
    tq.close_task("t")
    seen, lock = [], threading.Lock()

    def worker(w):
        for _, ix in tq.dataloader("t", batch, consumer=f"dp{w}"):
            with lock:
                seen.extend(ix)

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(n_consumers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10.0)
    assert sorted(seen) == idxs


@settings(max_examples=20, deadline=None)
@given(writes=st.lists(st.tuples(st.integers(0, 19),
                                 st.sampled_from(["a", "b"])),
                       min_size=1, max_size=60))
def test_property_ready_iff_all_columns(writes):
    """A row is schedulable iff *all* required columns have been written."""
    c = TransferQueueController("t", ["a", "b"], capacity=20)
    written = {}
    for idx, col in writes:
        c.notify(idx, col)
        written.setdefault(idx, set()).add(col)
    expect = sum(1 for cols in written.values() if cols == {"a", "b"})
    assert c.num_ready() == expect
