"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

key = jax.random.PRNGKey(0)


def k(i):
    return jax.random.fold_in(key, i)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),       # MHA
    (2, 256, 4, 2, 64),       # GQA 2:1
    (1, 256, 8, 1, 32),       # MQA
    (2, 128, 4, 4, 128),      # MXU-aligned head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention(B, S, H, KV, hd, dtype, window):
    from repro.kernels.flash_attention import (flash_attention,
                                               flash_attention_ref)
    q = jax.random.normal(k(1), (B, S, H, hd), dtype)
    kk = jax.random.normal(k(2), (B, S, KV, hd), dtype)
    v = jax.random.normal(k(3), (B, S, KV, hd), dtype)
    out = flash_attention(q, kk, v, window=window)
    ref = flash_attention_ref(q, kk, v, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_odd_shape_falls_back():
    from repro.kernels.flash_attention import flash_attention, \
        flash_attention_ref
    q = jax.random.normal(k(1), (1, 100, 2, 16))
    kv = jax.random.normal(k(2), (1, 100, 2, 16))
    np.testing.assert_allclose(flash_attention(q, kv, kv),
                               flash_attention_ref(q, kv, kv),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd", [
    (2, 1024, 4, 2, 64),
    (1, 2048, 8, 8, 32),
    (3, 512, 4, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, S, H, KV, hd, dtype):
    from repro.kernels.decode_attention import (decode_attention,
                                                decode_attention_ref)
    q = jax.random.normal(k(1), (B, 1, H, hd), dtype)
    kc = jax.random.normal(k(2), (B, S, KV, hd), dtype)
    vc = jax.random.normal(k(3), (B, S, KV, hd), dtype)
    fill = jax.random.randint(k(4), (B,), 1, S + 1)
    valid = jnp.arange(S)[None, :] < fill[:, None]
    out = decode_attention(q, kc, vc, valid)
    ref = decode_attention_ref(q, kc, vc, valid)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# rglru_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,W", [(1, 256, 128), (2, 512, 256), (3, 128, 384)])
def test_rglru_scan(B, S, W):
    from repro.kernels.rglru_scan import rglru_scan, rglru_scan_ref
    a = jax.random.uniform(k(1), (B, S, W), minval=0.4, maxval=0.999)
    b = jax.random.normal(k(2), (B, S, W))
    np.testing.assert_allclose(rglru_scan(a, b), rglru_scan_ref(a, b),
                               atol=2e-4, rtol=2e-4)


def test_rglru_scan_block_boundary_carry():
    """State must carry exactly across sequence-block boundaries."""
    from repro.kernels.rglru_scan import rglru_scan, rglru_scan_ref
    a = jnp.full((1, 512, 128), 0.9)
    b = jnp.ones((1, 512, 128))
    out = rglru_scan(a, b, block_s=128)
    ref = rglru_scan_ref(a, b)
    np.testing.assert_allclose(out, ref, atol=1e-3, rtol=1e-4)


# ---------------------------------------------------------------------------
# mamba_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,D,N", [(1, 128, 128, 16), (2, 256, 256, 8)])
def test_mamba_scan(B, S, D, N):
    from repro.kernels.mamba_scan import mamba_scan, mamba_scan_ref
    x = jax.random.normal(k(1), (B, S, D))
    dt = 0.1 * jax.nn.softplus(jax.random.normal(k(2), (B, S, D)))
    a = -jnp.abs(jax.random.normal(k(3), (D, N)))
    b = jax.random.normal(k(4), (B, S, N))
    c = jax.random.normal(k(5), (B, S, N))
    np.testing.assert_allclose(mamba_scan(x, dt, a, b, c),
                               mamba_scan_ref(x, dt, a, b, c),
                               atol=2e-4, rtol=2e-3)


# ---------------------------------------------------------------------------
# grpo_logprob
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,V", [(256, 2048), (512, 4096), (512, 8192)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grpo_logprob(N, V, dtype):
    from repro.kernels.grpo_logprob import grpo_logprob, grpo_logprob_ref
    logits = (5 * jax.random.normal(k(1), (N, V))).astype(dtype)
    tgt = jax.random.randint(k(2), (N,), 0, V)
    lp, ent = grpo_logprob(logits, tgt)
    lpr, entr = grpo_logprob_ref(logits.astype(jnp.float32), tgt)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(lp, lpr, atol=tol, rtol=tol)
    np.testing.assert_allclose(ent, entr, atol=5 * tol, rtol=5 * tol)


def test_grpo_logprob_batched_shape():
    from repro.kernels.grpo_logprob.ops import grpo_logprob
    logits = jax.random.normal(k(1), (2, 8, 512))
    tgt = jax.random.randint(k(2), (2, 8), 0, 512)
    lp, ent = grpo_logprob(logits, tgt)
    assert lp.shape == (2, 8) and ent.shape == (2, 8)
    assert bool((ent >= -1e-3).all())  # entropy non-negative


@pytest.mark.parametrize("N,V", [(100, 1000), (7, 131), (257, 2049)])
def test_grpo_logprob_non_divisible_shapes(N, V):
    """Pad-and-mask: arbitrary (N, V) run through the kernel, no
    block-divisibility requirement."""
    from repro.kernels.grpo_logprob import grpo_logprob, grpo_logprob_ref
    logits = 5 * jax.random.normal(k(1), (N, V))
    tgt = jax.random.randint(k(2), (N,), 0, V)
    lp, ent = grpo_logprob(logits, tgt)
    assert lp.shape == (N,) and ent.shape == (N,)
    lpr, entr = grpo_logprob_ref(logits, tgt)
    np.testing.assert_allclose(lp, lpr, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(ent, entr, atol=5e-4, rtol=5e-4)


# ---------------------------------------------------------------------------
# fused_rl_loss: logprob + entropy + k3 KL + clipped surrogate, custom VJP
# ---------------------------------------------------------------------------

def _rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return float(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1.0))


def _fused_inputs(N, V, dtype=jnp.float32):
    logits = (5 * jax.random.normal(k(11), (N, V))).astype(dtype)
    tgt = jax.random.randint(k(12), (N,), 0, V)
    old = 0.1 * jax.random.normal(k(13), (N,)) - 2.0
    ref = 0.1 * jax.random.normal(k(14), (N,)) - 2.0
    adv = jax.random.normal(k(15), (N,))
    return logits, tgt, old, ref, adv


_OUT_NAMES = ("logprob", "entropy", "kl", "policy_loss", "ratio")


@pytest.mark.parametrize("N,V", [(16, 256), (13, 300), (7, 131)])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_fused_rl_loss_values(N, V, use_pallas):
    from repro.kernels.fused_rl_loss import fused_rl_loss, fused_rl_loss_ref
    logits, tgt, old, ref, adv = _fused_inputs(N, V)
    outs = fused_rl_loss(logits, tgt, old, ref, adv,
                         use_pallas=use_pallas, block_n=8, block_v=128)
    refs = fused_rl_loss_ref(logits, tgt, old, ref, adv)
    for name, o, r in zip(_OUT_NAMES, outs, refs):
        assert o.shape == (N,), name
        assert _rel_err(o, r) < 1e-4, name


@pytest.mark.parametrize("N,V", [(16, 256), (13, 300)])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_fused_rl_loss_grads_match_reference(N, V, use_pallas):
    """Hand-written VJP (one streaming vocab pass, softmax recomputed from
    saved statistics) vs jax.grad through the materializing reference —
    gradients for logits, old/ref logprobs and advantages all line up."""
    from repro.kernels.fused_rl_loss import fused_rl_loss, fused_rl_loss_ref
    logits, tgt, old, ref, adv = _fused_inputs(N, V)
    w = [0.3, -0.2, 0.7, 1.0, 0.1]    # mix every output into the scalar

    def scalarize(fn):
        def f(lg, o, r, a):
            outs = fn(lg, tgt, o, r, a)
            return sum(wi * jnp.sum(oi) for wi, oi in zip(w, outs))
        return f

    def fused(lg, t, o, r, a):
        return fused_rl_loss(lg, t, o, r, a, use_pallas=use_pallas,
                             block_n=8, block_v=128)

    g_f = jax.grad(scalarize(fused), argnums=(0, 1, 2, 3))(
        logits, old, ref, adv)
    g_r = jax.grad(scalarize(fused_rl_loss_ref), argnums=(0, 1, 2, 3))(
        logits, old, ref, adv)
    for name, gf, gr in zip(("dlogits", "dold", "dref", "dadv"), g_f, g_r):
        assert _rel_err(gf, gr) < 1e-4, name


def test_fused_rl_loss_bf16_smoke():
    from repro.kernels.fused_rl_loss import fused_rl_loss, fused_rl_loss_ref
    logits, tgt, old, ref, adv = _fused_inputs(16, 256, jnp.bfloat16)
    outs = fused_rl_loss(logits, tgt, old, ref, adv, use_pallas=True,
                         block_n=8, block_v=128)
    refs = fused_rl_loss_ref(logits.astype(jnp.float32), tgt, old, ref, adv)
    for name, o, r in zip(_OUT_NAMES, outs, refs):
        assert _rel_err(o, r) < 5e-2, name


def test_fused_rl_loss_batched_shape():
    from repro.kernels.fused_rl_loss import fused_rl_loss
    B, S, V = 2, 9, 260
    logits = jax.random.normal(k(21), (B, S, V))
    tgt = jax.random.randint(k(22), (B, S), 0, V)
    old = jnp.zeros((B, S))
    refp = jnp.zeros((B, S))
    adv = jnp.ones((B, S))
    outs = fused_rl_loss(logits, tgt, old, refp, adv, block_n=8, block_v=128)
    for o in outs:
        assert o.shape == (B, S)
    lp, ent, kl, _, _ = outs
    assert bool((ent >= -1e-3).all())
    assert bool((kl >= -1e-5).all())   # k3 estimator is non-negative
