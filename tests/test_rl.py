"""RL algorithm layer: advantages, losses, GRPO/PPO steps, reward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.data.tokenizer import ByteTokenizer
from repro.rl import (GRPOConfig, PPOConfig, clipped_policy_loss, gae,
                      grpo_advantages, grpo_train_step, init_critic_params,
                      kl_penalty, math_reward, ppo_train_step)
from repro.training.optimizer import OptimizerConfig
from repro.training.train_state import TrainState

tok = ByteTokenizer()


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(-5, 5, allow_nan=False), min_size=2, max_size=16))
def test_grpo_advantages_normalized(rewards):
    adv = np.asarray(grpo_advantages(np.asarray(rewards, np.float32)))
    assert abs(adv.mean()) < 1e-4
    if np.std(rewards) > 1e-3:
        assert abs(adv.std() - 1.0) < 0.05
    else:
        assert np.abs(adv).max() < 1.0  # degenerate group -> ~zero


def test_gae_terminal_matches_reward():
    adv, ret = gae([1.0, 0.0, 2.0], [0.0, 0.0, 0.0, 0.0], gamma=1.0, lam=1.0)
    assert ret[0] == pytest.approx(3.0)
    assert adv[-1] == pytest.approx(2.0)


def test_clipped_policy_loss_clip_behavior():
    lp_old = jnp.zeros((1, 4))
    mask = jnp.ones((1, 4))
    adv = jnp.asarray([1.0])
    # big positive ratio with positive advantage is clipped at 1+eps
    lp_new = jnp.full((1, 4), 2.0)
    loss, stats = clipped_policy_loss(lp_new, lp_old, adv, mask, clip_eps=0.2)
    assert loss == pytest.approx(-1.2, abs=1e-5)
    assert float(stats["clip_frac"]) == 1.0
    # ratio 1 -> loss = -A
    loss2, _ = clipped_policy_loss(lp_old, lp_old, adv, mask)
    assert loss2 == pytest.approx(-1.0, abs=1e-6)


def test_kl_penalty_nonnegative_zero_at_equal():
    lp = jnp.asarray([[0.5, -1.0]])
    mask = jnp.ones((1, 2))
    assert kl_penalty(lp, lp, mask) == pytest.approx(0.0, abs=1e-7)
    assert float(kl_penalty(lp, lp - 0.3, mask)) > 0


def test_math_reward():
    assert math_reward(12, tok.encode("12", add_bos=False)) == 1.0
    assert math_reward(12, tok.encode("the answer is 12",
                                      add_bos=False)) == pytest.approx(0.2)
    assert math_reward(12, tok.encode("7", add_bos=False)) == pytest.approx(-0.1)
    assert math_reward(-3, tok.encode("-3", add_bos=False)) == 1.0
    assert math_reward(12, tok.encode("123", add_bos=False)) < 1.0


def _rl_batch(cfg, B=4, S=12, seed=0):
    rng = np.random.default_rng(seed)
    adv = rng.normal(size=B).astype(np.float32)
    return {
        "tokens": jnp.asarray(rng.integers(3, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "response_mask": jnp.asarray(rng.integers(0, 2, (B, S)),
                                     jnp.float32),
        "old_logprob": jnp.asarray(-2 + 0.1 * rng.normal(size=(B, S)),
                                   jnp.float32),
        "advantage": jnp.asarray(adv),
    }


def test_grpo_step_moves_logprobs_toward_advantage(tiny_dense_cfg):
    """After several updates on a fixed batch, logprobs of positive-
    advantage samples should rise relative to negative ones."""
    from repro.models import forward, init_params
    from repro.rl.loss import token_logprobs
    cfg = tiny_dense_cfg
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = TrainState.create(params)
    batch = _rl_batch(cfg)
    batch["advantage"] = jnp.asarray([2.0, 2.0, -2.0, -2.0])
    rl, opt = GRPOConfig(clip_eps=10.0), OptimizerConfig(lr=1e-3,
                                                         warmup_steps=1)

    def mean_lp(params):
        logits, _ = forward(params, cfg, {"tokens": batch["tokens"]})
        lp, _ = token_logprobs(logits[:, :-1], batch["tokens"][:, 1:])
        m = batch["response_mask"][:, 1:]
        return (lp * m).sum(1) / jnp.maximum(m.sum(1), 1)

    before = mean_lp(state.params)
    for _ in range(5):
        state, metrics = grpo_train_step(state, cfg, rl, opt, batch)
    after = mean_lp(state.params)
    delta = np.asarray(after - before)
    assert delta[:2].mean() > delta[2:].mean()


def test_ppo_train_step(tiny_dense_cfg):
    from repro.models import init_params
    cfg = tiny_dense_cfg
    actor = TrainState.create(init_params(jax.random.PRNGKey(0), cfg))
    critic = TrainState.create(init_critic_params(jax.random.PRNGKey(1), cfg))
    B, S = 2, 10
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(3, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "response_mask": jnp.ones((B, S), jnp.float32),
        "old_logprob": -2 * jnp.ones((B, S), jnp.float32),
        "advantage": jnp.asarray(rng.normal(size=(B, S)), jnp.float32),
        "returns": jnp.ones((B, S), jnp.float32),
        "old_values": jnp.zeros((B, S), jnp.float32),
    }
    new_actor, new_critic, metrics = ppo_train_step(
        actor, critic, cfg, PPOConfig(), OptimizerConfig(lr=1e-4), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["value_loss"]))
    assert int(new_actor.step) == 1 and int(new_critic.step) == 1
