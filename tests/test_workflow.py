"""Async workflow: mode semantics, staleness invariants, weight sync."""
import time

import numpy as np
import pytest

from repro.core.workflow import (AsyncRLRunner, EventLog, WeightChannel,
                                 WeightReceiver, WeightSender,
                                 WorkflowConfig)


class SleepRollout:
    def __init__(self, dt=0.015, group=2):
        self.dt, self.group = dt, group

    def generate(self, params, prompts, rng):
        time.sleep(self.dt * len(prompts))
        return [dict(prompt=p, response=[1, 2], logprob=[0.0, 0.0],
                     response_mask=[0, 1], reward=1.0, advantage=0.5,
                     token_len=2)
                for p in prompts for _ in range(self.group)]


class SleepTrain:
    def __init__(self, dt=0.003):
        self.params = {"w": np.zeros(3)}
        self.dt = dt

    def update(self, batch):
        time.sleep(self.dt * len(batch["version"]))
        return {"loss": 0.0}


def _run(mode, **kw):
    base = dict(num_rollout_workers=2, rollout_batch=2, train_micro_batch=4,
                prompts_per_step=8, group_size=2, num_steps=5)
    base.update(kw)
    cfg = WorkflowConfig(mode=mode, **base)
    return AsyncRLRunner(cfg, rollout_engine=SleepRollout(),
                         train_engine=SleepTrain(),
                         prompt_stream=lambda s: [[1, 2]] * 8).run()


def test_mode_ordering_and_staleness():
    rs = {m: _run(m) for m in ("baseline", "streaming", "async")}
    assert max(rs["baseline"].staleness_seen) == 0
    assert max(rs["streaming"].staleness_seen) == 0
    assert 1 <= max(rs["async"].staleness_seen) <= 2
    assert rs["async"].wall_time_s < rs["baseline"].wall_time_s
    assert rs["streaming"].wall_time_s < rs["baseline"].wall_time_s


def test_all_samples_trained_every_mode():
    for m in ("baseline", "streaming", "async"):
        r = _run(m)
        assert len(r.staleness_seen) == r.samples_trained == 5 * 16


def test_staggered_substep_async():
    r = _run("async", staggered=True)
    assert max(r.staleness_seen) <= 2
    assert len(r.staleness_seen) == 80


def test_staleness_property_many_seeds():
    """Hard invariant: async staleness never exceeds cfg.staleness + 1."""
    for workers in (1, 2, 3):
        r = _run("async", num_rollout_workers=workers)
        assert max(r.staleness_seen) <= 2
        assert np.mean(r.staleness_seen) <= 1.0 + 1e-9


def test_weight_sender_receiver_versions():
    ch = WeightChannel()
    s = WeightSender(ch, mode="async")
    r = WeightReceiver(ch, {"w": np.zeros(2)}, version=0)
    s.publish({"w": np.ones(2)}, 1)
    s.flush()
    assert r.staged_version() == 1
    assert r.maybe_swap()
    assert r.version == 1 and float(r.params["w"][0]) == 1.0
    assert not r.maybe_swap()  # idempotent
    # stale publishes never regress
    s.publish({"w": np.zeros(2)}, 1)
    s.flush()
    s.publish({"w": 2 * np.ones(2)}, 3)
    s.flush()
    assert r.wait_and_swap(2, timeout=1.0)
    assert r.version == 3


def test_weight_channel_bandwidth_delay():
    ch = WeightChannel(bandwidth_gbps=1.0)  # 1 Gb/s
    s = WeightSender(ch, mode="sync")
    payload = {"w": np.zeros(125_000, np.int8)}  # 125 KB -> ~1 ms
    t0 = time.monotonic()
    s.publish(payload, 1)
    assert time.monotonic() - t0 >= 0.0009
    assert ch.bytes_sent == 125_000


def test_event_log_bubble_fraction():
    log = EventLog()
    t0 = time.monotonic()
    log.record("i0", "generate", t0, t0 + 1.0)
    log.record("i0", "wait", t0 + 1.0, t0 + 2.0)
    bf = log.bubble_fraction()
    assert abs(bf["i0"] - 0.5) < 1e-6
    assert "i0" in log.render_gantt(width=20)
