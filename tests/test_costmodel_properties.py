"""Hypothesis property tests on the cost model / simulator invariants —
these are the planner's decision inputs, so monotonicity bugs would
silently corrupt resource plans."""
import pytest
from _hyp import given, settings, st

from repro.configs import get_config
from repro.core.planner import (ClusterPlan, Workload, forward_flops,
                                kv_cache_bytes, roofline_terms, simulate,
                                step_collective_bytes)

CFG = get_config("qwen2_5_7b")
MOE = get_config("deepseek_v2_236b")


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 64), s=st.sampled_from([128, 1024, 4096]))
def test_flops_monotone_in_batch_and_seq(b, s):
    assert forward_flops(CFG, b + 1, s) > forward_flops(CFG, b, s)
    assert forward_flops(CFG, b, 2 * s) > 2 * forward_flops(CFG, b, s) * 0.99


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 128), ln=st.sampled_from([1024, 32768, 524288]))
def test_cache_bytes_scale(b, ln):
    assert kv_cache_bytes(CFG, b, ln) == pytest.approx(
        b * kv_cache_bytes(CFG, 1, ln), rel=1e-6)
    # MLA cache strictly smaller than GQA-equivalent at same shape
    mla = get_config("minicpm3_4b")
    gqa_equiv = b * mla.num_layers * ln * 2 * mla.num_kv_heads * 64 * 2
    assert kv_cache_bytes(mla, b, ln) < gqa_equiv


@settings(max_examples=20, deadline=None)
@given(tp=st.sampled_from([2, 4, 8, 16]))
def test_tp_allreduce_grows_with_tp_fraction(tp):
    """For fixed total chips, higher tp -> more TP collective per chip."""
    n = 256
    co_lo = step_collective_bytes(CFG, "train_4k",
                                  {"data": n // tp, "model": tp})
    co_hi = step_collective_bytes(CFG, "train_4k",
                                  {"data": n // (2 * tp) or 1,
                                   "model": 2 * tp})
    if 2 * tp <= 32:
        assert co_hi["tp_allreduce"] > co_lo["tp_allreduce"]


def test_device_limit_reduces_a2a_only():
    import dataclasses
    base = step_collective_bytes(MOE, "train_4k", {"data": 16, "model": 16})
    lim = step_collective_bytes(
        dataclasses.replace(MOE, moe_device_limit=2), "train_4k",
        {"data": 16, "model": 16})
    assert lim["moe_all2all"] < base["moe_all2all"]
    assert lim["tp_allreduce"] == base["tp_allreduce"]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_simulator_async_never_slower_than_separated(seed):
    w = Workload(prompts_per_step=64, group_size=4, num_steps=3)
    plan = ClusterPlan(128, 64, 64, 4, 8)
    sep = simulate(CFG, plan, w, "separated", seed=seed)
    asy = simulate(CFG, plan, w, "separated_async", seed=seed)
    assert asy["throughput_samples_per_s"] >= \
        sep["throughput_samples_per_s"] * 0.999


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_simulator_conserves_samples(seed):
    w = Workload(prompts_per_step=32, group_size=4, num_steps=4)
    plan = ClusterPlan(64, 32, 32, 4, 8)
    for mode in ("separated", "separated_tq", "separated_async"):
        r = simulate(CFG, plan, w, mode, seed=seed)
        implied = r["throughput_samples_per_s"] * r["wall_s"]
        assert implied == pytest.approx(
            w.num_steps * w.prompts_per_step * w.group_size, rel=1e-6)
