"""Durable run-level checkpointing + trainer crash recovery: atomic
versioned snapshots (LATEST pointer, keep-last-k retention, torn-write
fallback), warm in-process trainer restart through the supervised
StageRunner with zero lost or duplicated rows, cold ``fit(resume=...)``
reproducing an uninterrupted fixed-seed run bit-for-bit, and the
abnormal-exit flush path (final metrics sample + last run snapshot)."""
import json
import os
from types import SimpleNamespace

import jax
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core.obs import MetricsRegistry, render_report, scoped
from repro.core.recovery import RunCheckpointer
from repro.core.supervision import FaultConfig
from repro.core.workflow import (StageGraph, StageRunner, StageSpec,
                                 WorkflowConfig)


# ---------------------------------------------------------------------- #
# RunCheckpointer: atomic snapshots, LATEST pointer, retention            #
# ---------------------------------------------------------------------- #

def test_snapshot_roundtrip_latest_pointer_and_retention(tmp_path):
    reg = MetricsRegistry()
    ck = RunCheckpointer(str(tmp_path), keep_last=2, metrics=reg)
    like = {"w": np.zeros((2, 2), np.float32)}
    for step in (1, 2, 3):
        ck.save(step, {"trainer_version": step, "acked_uids": [0, step]},
                {"actor": {"w": np.full((2, 2), step, np.float32)}})
    # keep-last-k retention pruned snapshot 1; LATEST names the newest
    assert ck.list_snapshots() == ["snapshot-00000002", "snapshot-00000003"]
    assert (tmp_path / "LATEST").read_text().strip() == "snapshot-00000003"
    path = ck.resolve("auto")
    doc = ck.load(path)
    assert doc["step"] == 3 and doc["trainer_version"] == 3
    assert doc["engines"] == ["actor"] and doc["acked_uids"] == [0, 3]
    tree, step = ck.load_engine(path, "actor", like)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(tree["w"]),
                                  np.full((2, 2), 3, np.float32))
    # instrumentation: one write observed per snapshot, bytes accounted
    writes = reg.snapshot()["checkpoint_write_seconds"]["values"]
    assert sum(v["count"] for v in writes) == 3
    assert reg.get("checkpoint_bytes_total").value() > 0


def test_resolve_auto_skips_torn_and_corrupt_snapshots(tmp_path):
    ck = RunCheckpointer(str(tmp_path), keep_last=4,
                         metrics=MetricsRegistry())
    state = {"w": np.ones((2, 2), np.float32)}
    good = ck.save(1, {"trainer_version": 1}, {"actor": state})
    bad = ck.save(2, {"trainer_version": 2}, {"actor": state})
    # simulate a SIGKILL mid-write: a torn temp dir from a dead writer...
    torn = tmp_path / ".tmp-snapshot-00000003-dead"
    torn.mkdir()
    (torn / "run.json").write_text('{"schema": "asyncflow-run-snap')
    # ...and garbage over the newest committed snapshot's engine arrays
    with open(os.path.join(bad, "actor", "arrays.npz"), "wb") as f:
        f.write(b"\x00garbage")
    # LATEST still names the (now corrupt) newest; auto falls back to
    # the previous intact snapshot instead of trusting the pointer
    assert (tmp_path / "LATEST").read_text().strip() == "snapshot-00000002"
    assert ck.resolve("auto") == good
    # an explicit path to a torn snapshot raises instead of guessing
    with pytest.raises(FileNotFoundError):
        ck.resolve(bad)
    # the next committed save sweeps the dead writer's debris
    ck.save(4, {"trainer_version": 4}, {"actor": state})
    assert not torn.exists()


# ---------------------------------------------------------------------- #
# warm trainer restart through the stage graph (toy engines)              #
# ---------------------------------------------------------------------- #

def _toy_graph(enrich_fn=None):
    def gen(batch, *, params, rng, version=0, **kw):
        return {"rows": [dict(item=x, token_len=1)
                         for x in batch["prompt"] for _ in range(2)]}

    def enrich(batch, *, indices, **kw):
        return {"updates": {"score": [v + 1 for v in batch["item"]]}}

    def train(batch, **kw):
        return {"n": len(batch["version"])}

    g = StageGraph(source_columns=("prompt",))
    g.add(StageSpec("generate", inputs=("prompt",),
                    outputs=("item", "version"), fn=gen, kind="generate"))
    g.add(StageSpec("enrich", inputs=("item",), outputs=("score",),
                    fn=enrich_fn or enrich))
    g.add(StageSpec("actor_update", inputs=("item", "score", "version"),
                    engine="trainer", fn=train, kind="train",
                    drives_steps=True))
    return g


def _toy_runner(graph=None, metrics=None, **cfg_kw):
    cfg_kw.setdefault("mode", "streaming")
    cfg_kw.setdefault("num_rollout_workers", 2)
    cfg_kw.setdefault("rollout_batch", 2)
    cfg_kw.setdefault("train_micro_batch", 4)
    cfg_kw.setdefault("prompts_per_step", 4)
    cfg_kw.setdefault("group_size", 2)
    cfg_kw.setdefault("num_steps", 3)
    return StageRunner(
        WorkflowConfig(**cfg_kw), graph or _toy_graph(),
        engines={"trainer": SimpleNamespace(params={"w": 0})},
        prompt_stream=lambda s: [1, 2, 3, 4],
        metrics=metrics or MetricsRegistry())


def test_trainer_kill_warm_restart_zero_lost_or_duplicated(tmp_path):
    """Kill the train worker mid-run (deterministic call-ordinal fault):
    its leased rows requeue at the front, the driver warm-restarts from
    the newest snapshot in the same process while generators keep
    streaming, and the trained totals match a fault-free run exactly."""
    reg = MetricsRegistry()
    # 8 samples/step at micro-batch 4 -> 2 train calls per step; ordinal
    # 3 is the second micro-batch of step 1 (step-0 snapshot committed)
    runner = _toy_runner(metrics=reg, checkpoint_dir=str(tmp_path),
                         faults=FaultConfig(seed=0,
                                            stages=("actor_update",),
                                            crash_on_calls=(3,)),
                         heartbeat_timeout_s=30.0)
    r = runner.run()
    assert r.samples_trained == 3 * 8            # zero lost rows
    assert reg.get("trainer_restarts_total").value() == 1
    assert reg.get("rows_requeued_total").value(task="actor_update") >= 4
    assert reg.get("rows_dropped_duplicate_total").value() == 0
    assert reg.get("faults_injected_total").value(
        stage="actor_update", kind="crash") == 1
    # intact snapshots on disk, the newest at the final step boundary
    ck = RunCheckpointer(str(tmp_path), metrics=MetricsRegistry())
    doc = ck.load(ck.resolve("auto"))
    assert doc["step"] == 3 and doc["samples_trained"] == 24
    # the telemetry report grew a recovery summary line
    report = render_report(r.telemetry)
    assert "recovery:" in report and "1 trainer restarts" in report


def test_trainer_restart_budget_exhaustion_fails_the_run(tmp_path):
    reg = MetricsRegistry()
    runner = _toy_runner(metrics=reg, checkpoint_dir=str(tmp_path),
                         faults=FaultConfig(seed=0,
                                            stages=("actor_update",),
                                            crash_on_calls=(0, 1, 2, 3)),
                         max_trainer_restarts=2, heartbeat_timeout_s=30.0)
    with pytest.raises(RuntimeError, match=r"stage 'actor_update'"):
        runner.run()
    assert reg.get("trainer_restarts_total").value() == 2


def test_trainer_crash_without_checkpointing_is_fatal():
    """No checkpoint_dir -> no snapshots to warm-restart from: a trainer
    crash stays fatal with first-failure attribution (seed behavior)."""
    runner = _toy_runner(faults=FaultConfig(seed=0,
                                            stages=("actor_update",),
                                            crash_on_calls=(0,)),
                         heartbeat_timeout_s=30.0)
    with pytest.raises(RuntimeError, match=r"stage 'actor_update'"):
        runner.run()


def test_abnormal_exit_flushes_final_sample_and_last_snapshot(tmp_path):
    """A fatal (non-crash) stage error still flushes one final metrics
    sample to the JSONL sink and leaves an intact run snapshot behind,
    so the post-mortem sees terminal counters and a cold resume can pick
    up at the newest completed boundary."""
    jsonl = tmp_path / "metrics.jsonl"
    snaps = tmp_path / "snaps"

    def bad_enrich(batch, *, indices, **kw):
        raise KeyError("enrich exploded")

    runner = _toy_runner(graph=_toy_graph(enrich_fn=bad_enrich),
                         checkpoint_dir=str(snaps),
                         metrics_jsonl=str(jsonl))
    with pytest.raises(RuntimeError, match="enrich exploded"):
        runner.run()
    lines = [json.loads(ln) for ln in jsonl.read_text().splitlines()]
    assert lines and "metrics" in lines[-1]
    ck = RunCheckpointer(str(snaps), metrics=MetricsRegistry())
    path = ck.resolve("auto")
    assert path is not None and ck.load(path)["step"] == 0


# ---------------------------------------------------------------------- #
# real engines: warm restart + cold resume bit-identity                   #
# ---------------------------------------------------------------------- #

def _real_tcfg(**overrides):
    from repro.api import TrainerConfig
    kw = dict(num_steps=4, prompts_per_step=2, group_size=2,
              rollout_workers=1, rollout_batch=2, train_micro_batch=4,
              max_new_tokens=6, seq_len=24, mode="streaming",
              num_storage_units=1, seed=0, rollout_backend="continuous",
              cb_slots=2, heartbeat_timeout_s=30.0,
              checkpoint_interval_steps=1)
    kw.update(overrides)
    return TrainerConfig(**kw)


def _fit_scoped(tcfg, cfg, params, resume=None):
    from repro.api import Trainer
    with scoped() as reg:
        r = Trainer(tcfg, model_cfg=cfg, params=params).fit(resume=resume)
        snap = reg.snapshot()
    return r, snap


def _assert_metrics_identical(a, b):
    assert len(a) == len(b)
    for ma, mb in zip(a, b):
        assert ma["step"] == mb["step"]
        for k in ("loss", "policy_loss", "grad_norm", "mean_reward"):
            np.testing.assert_array_equal(np.asarray(ma[k]),
                                          np.asarray(mb[k]), err_msg=k)


def test_real_trainer_kill_warm_restart_bit_identical(tmp_path):
    """Kill the real train stage at a deterministic call ordinal: the
    driver warm-restarts from its last snapshot while the continuous-
    batching generator keeps streaming, redoes the lost step on the
    requeued rows, and the full metric trace matches an uninterrupted
    fixed-seed run bit-for-bit — zero lost or duplicated rows."""
    from repro.models import init_params
    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    # 4 samples/step = one train call per step; ordinal 2 kills the
    # trainer entering step 2 (steps 0-1 already snapshotted)
    faults = FaultConfig(seed=0, stages=("actor_update",),
                         crash_on_calls=(2,))
    r_clean, _ = _fit_scoped(
        _real_tcfg(checkpoint_dir=str(tmp_path / "clean")), cfg, params)
    r_kill, snap = _fit_scoped(
        _real_tcfg(checkpoint_dir=str(tmp_path / "kill"), faults=faults),
        cfg, params)
    restarts = sum(v["value"] for v in snap.get(
        "trainer_restarts_total", {}).get("values", []))
    assert restarts == 1
    assert r_kill.samples_trained == r_clean.samples_trained == 16
    _assert_metrics_identical(r_clean.metrics, r_kill.metrics)
    assert r_kill.staleness_seen == r_clean.staleness_seen


def test_cold_resume_bit_identical_to_uninterrupted_run(tmp_path):
    """Two-phase cold resume: phase one trains steps 0-1 with snapshots
    and exits; a FRESH Trainer (new engines, re-initialized params) runs
    ``fit(resume="auto")`` and finishes steps 2-3. Engine state, the
    published weight version, sampling counter bases, the dataset cursor
    and the queue uid watermark are all restored, so the stitched run's
    metrics equal an uninterrupted 4-step run bit-for-bit."""
    from repro.models import init_params
    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ckpt = str(tmp_path / "run")
    r_full, _ = _fit_scoped(_real_tcfg(mode="baseline"), cfg, params)
    r_half, _ = _fit_scoped(
        _real_tcfg(mode="baseline", num_steps=2, checkpoint_dir=ckpt),
        cfg, params)
    # a restarted process re-inits from the same seed, then restores
    fresh = init_params(jax.random.PRNGKey(0), cfg)
    r_res, _ = _fit_scoped(_real_tcfg(mode="baseline", checkpoint_dir=ckpt),
                           cfg, fresh, resume="auto")
    assert r_res.samples_trained == r_full.samples_trained == 16
    # the resumed result carries phase one's metrics verbatim as prefix
    _assert_metrics_identical(r_half.metrics, r_res.metrics[:2])
    _assert_metrics_identical(r_full.metrics, r_res.metrics)
    assert r_res.staleness_seen == r_full.staleness_seen


def test_resume_auto_with_empty_dir_starts_fresh(tmp_path):
    """resume="auto" with no snapshot on disk silently starts a fresh
    run (step 0), while an explicit missing path raises."""
    from repro.api import Trainer
    from repro.models import init_params
    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    tcfg = _real_tcfg(mode="baseline", num_steps=1,
                      checkpoint_dir=str(tmp_path / "empty"))
    r, _ = _fit_scoped(tcfg, cfg, params, resume="auto")
    assert r.samples_trained == 4 and len(r.metrics) == 1
    with pytest.raises(FileNotFoundError):
        Trainer(_real_tcfg(mode="baseline",
                           checkpoint_dir=str(tmp_path / "empty2")),
                model_cfg=cfg, params=params).fit(
            resume=str(tmp_path / "nowhere" / "snapshot-00000007"))
