"""Rollout sampling: behavior-logprob consistency and EOS handling."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import ByteTokenizer
from repro.models import forward
from repro.rl.loss import token_logprobs
from repro.rl.sampling import generate

tok = ByteTokenizer()


def test_generate_shapes_and_masks(tiny_dense_cfg, tiny_dense_params):
    prompts = [tok.encode("1+2="), tok.encode("10-3=")]
    rows = generate(tiny_dense_params, tiny_dense_cfg, prompts, 0,
                    max_new_tokens=6)
    assert len(rows) == 2
    max_len = max(len(q) for q in prompts)
    pad_len = ((max_len + 7) // 8) * 8   # bucketed prompt padding
    for p, r in zip(prompts, rows):
        total = pad_len + 6
        assert r["tokens"].shape == (total,)
        assert r["logprobs"].shape == (total,)
        assert r["prompt_len"] == len(p)
        # prompt tokens are preserved
        np.testing.assert_array_equal(r["tokens"][:len(p)], p)
        # response mask starts exactly at prompt end
        assert r["response_mask"][len(p) - 1] == 0
        assert r["response_mask"][len(p)] in (0.0, 1.0)


def test_behavior_logprobs_match_forward(tiny_dense_cfg, tiny_dense_params):
    """old_logprob from the rollout must equal the training-side logprob of
    the same tokens under the same params (the on-policy ratio==1 check)."""
    cfg, params = tiny_dense_cfg, tiny_dense_params
    prompts = [tok.encode("3+4=")] * 2
    rows = generate(params, cfg, prompts, 7, max_new_tokens=5,
                    temperature=1.0)
    toks = jnp.asarray(np.stack([r["tokens"] for r in rows]))
    logits, _ = forward(params, cfg, {"tokens": toks})
    lp_train, _ = token_logprobs(logits[:, :-1], toks[:, 1:])
    lp_rollout = np.stack([r["logprobs"] for r in rows])[:, 1:]
    mask = np.stack([r["response_mask"] for r in rows])[:, 1:]
    diff = np.abs(np.asarray(lp_train) - lp_rollout) * mask
    assert diff.max() < 0.05, diff.max()


def test_eos_trims_response(tiny_dense_cfg, tiny_dense_params):
    prompts = [tok.encode("5+5=")]
    rows = generate(tiny_dense_params, tiny_dense_cfg, prompts, 3,
                    max_new_tokens=8)
    r = rows[0]
    ids = r["response_ids"]
    eos_pos = np.where(ids == tok.eos_id)[0]
    if len(eos_pos):
        assert len(ids) == eos_pos[0] + 1
        # mask is zero beyond EOS
        assert r["response_mask"][r["prompt_len"] + len(ids):].sum() == 0


def test_generation_deterministic_per_seed(tiny_dense_cfg,
                                           tiny_dense_params):
    prompts = [tok.encode("2+2=")]
    a = generate(tiny_dense_params, tiny_dense_cfg, prompts, 42,
                 max_new_tokens=6)
    b = generate(tiny_dense_params, tiny_dense_cfg, prompts, 42,
                 max_new_tokens=6)
    np.testing.assert_array_equal(a[0]["tokens"], b[0]["tokens"])
    c = generate(tiny_dense_params, tiny_dense_cfg, prompts, 43,
                 max_new_tokens=6)
    assert not np.array_equal(a[0]["tokens"], c[0]["tokens"]) or True
