import dataclasses

import jax
import pytest

from repro.configs import get_config
from repro.data.tokenizer import ByteTokenizer


def tiny_cfg(arch="qwen2_5_7b", **overrides):
    """2-layer, d64 variant with byte-tokenizer vocab (CPU-fast)."""
    base = dict(num_layers=2, d_model=64, d_ff=128, num_heads=2,
                num_kv_heads=2, head_dim=32,
                vocab_size=ByteTokenizer.vocab_size)
    base.update(overrides)
    return dataclasses.replace(get_config(arch).reduced(), **base)


@pytest.fixture(scope="session")
def tiny_dense_cfg():
    return tiny_cfg()


@pytest.fixture(scope="session")
def tiny_dense_params(tiny_dense_cfg):
    from repro.models import init_params
    return init_params(jax.random.PRNGKey(0), tiny_dense_cfg)
