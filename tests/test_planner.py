"""Planner: cost model sanity + simulator semantics + plan search."""
import pytest

from repro.configs import get_config
from repro.core.planner import (HW, ClusterPlan, Workload, candidate_plans,
                                forward_flops, plan_resources,
                                roofline_terms, simulate, step_flops)


def test_forward_flops_scales_linearly_in_batch():
    cfg = get_config("qwen2_5_7b")
    f1 = forward_flops(cfg, 1, 2048)
    f2 = forward_flops(cfg, 2, 2048)
    assert f2 == pytest.approx(2 * f1, rel=1e-6)


def test_forward_flops_close_to_2nd():
    """For a dense model at moderate S, flops ≈ 2·N·D within 2x."""
    cfg = get_config("qwen2_5_7b")
    S, B = 2048, 1
    est = forward_flops(cfg, B, S)
    twnd = 2.0 * cfg.param_count() * B * S
    assert 0.8 * twnd < est < 2.0 * twnd


def test_moe_flops_use_active_params():
    moe = get_config("deepseek_v2_236b")
    est = forward_flops(moe, 1, 2048)
    act = 2.0 * moe.active_param_count() * 2048
    tot = 2.0 * moe.param_count() * 2048
    assert est < 0.5 * tot
    assert est > 0.5 * act


def test_step_flops_train_is_3x_forward():
    cfg = get_config("minicpm_2b")
    assert step_flops(cfg, "train_4k") == pytest.approx(
        3 * forward_flops(cfg, 256, 4096), rel=1e-9)


def test_roofline_terms_structure():
    cfg = get_config("qwen1_5_32b")
    rt = roofline_terms(cfg, "train_4k", {"data": 16, "model": 16})
    assert rt["n_chips"] == 256
    assert rt["bottleneck"] in ("compute", "memory", "collective")
    assert rt["t_step_lower_bound"] == max(rt["t_compute"], rt["t_memory"],
                                           rt["t_collective"])
    for k in ("t_compute", "t_memory", "t_collective"):
        assert rt[k] > 0


def test_decode_memory_bound():
    """Single-token decode must be memory-bound (weights read per token)."""
    cfg = get_config("qwen1_5_32b")
    rt = roofline_terms(cfg, "decode_32k", {"data": 16, "model": 16})
    assert rt["t_memory"] > rt["t_compute"]


def test_simulator_mode_ordering():
    cfg = get_config("qwen2_5_7b")
    w = Workload(prompts_per_step=128, group_size=8, num_steps=4)
    plan = ClusterPlan(256, 128, 128, 4, 8)
    r = {m: simulate(cfg, plan, w, m)["throughput_samples_per_s"]
         for m in ("separated", "separated_tq", "separated_async")}
    assert r["separated"] < r["separated_tq"] < r["separated_async"]


def test_simulator_scaling_improves_asyncflow_ratio():
    """The paper's headline: AsyncFlow's advantage over the colocated
    baseline grows with cluster size (Fig. 10)."""
    cfg = get_config("qwen2_5_7b")
    w = Workload(prompts_per_step=256, group_size=8, num_steps=4)
    ratios = []
    for n in (64, 256, 1024):
        plan = plan_resources(cfg, n, w).plan
        af = simulate(cfg, plan, w, "separated_async")
        verl = simulate(cfg, ClusterPlan(n, n, n, 4, 8,
                                         reshard_s=1.0 + 0.002 * n),
                        w, "colocated")
        ratios.append(af["throughput_samples_per_s"]
                      / verl["throughput_samples_per_s"])
    assert ratios[0] < ratios[-1]
    assert ratios[-1] > 1.2


def test_plan_resources_valid_split():
    cfg = get_config("qwen2_5_7b")
    w = Workload(prompts_per_step=64, group_size=4, num_steps=2)
    pr = plan_resources(cfg, 128, w)
    p = pr.plan
    assert p.rollout_chips + p.train_chips == 128
    assert p.rollout_chips % p.rollout_tp == 0
    assert pr.throughput > 0
    assert pr.candidates_scored == len(candidate_plans(128))


def test_hybrid_cost_model_profiling_path():
    cfg = get_config("qwen2_5_7b")
    w = Workload(prompts_per_step=64, group_size=4, num_steps=2)
    calls = []

    def profile_fn(plan):
        calls.append(plan)
        return {"decode_token_s": 0.001}

    pr = plan_resources(cfg, 128, w, profile_fn=profile_fn, profile_top_k=2)
    assert len(calls) == 2
    assert pr.throughput > 0


def test_profiling_hybrid_path_end_to_end():
    """§4.3 hybrid: measure reduced blocks on CPU, extrapolate, re-rank."""
    from repro.core.planner.profiling import make_profile_fn
    cfg = get_config("qwen2_5_7b")
    w = Workload(prompts_per_step=64, group_size=4, num_steps=2)
    pf = make_profile_fn(cfg, w)
    assert pf.raw["reduced_decode_s"] > 0
    assert pf.raw["reduced_train_s"] > 0
    pr = plan_resources(cfg, 128, w, profile_fn=pf, profile_top_k=2)
    assert pr.throughput > 0
