"""Planner: cost model sanity + simulator semantics + plan search."""
import pytest

from repro.configs import get_config
from repro.core.planner import (HW, ClusterPlan, Workload, candidate_plans,
                                forward_flops, plan_resources,
                                roofline_terms, simulate, step_flops)


def test_forward_flops_scales_linearly_in_batch():
    cfg = get_config("qwen2_5_7b")
    f1 = forward_flops(cfg, 1, 2048)
    f2 = forward_flops(cfg, 2, 2048)
    assert f2 == pytest.approx(2 * f1, rel=1e-6)


def test_forward_flops_close_to_2nd():
    """For a dense model at moderate S, flops ≈ 2·N·D within 2x."""
    cfg = get_config("qwen2_5_7b")
    S, B = 2048, 1
    est = forward_flops(cfg, B, S)
    twnd = 2.0 * cfg.param_count() * B * S
    assert 0.8 * twnd < est < 2.0 * twnd


def test_moe_flops_use_active_params():
    moe = get_config("deepseek_v2_236b")
    est = forward_flops(moe, 1, 2048)
    act = 2.0 * moe.active_param_count() * 2048
    tot = 2.0 * moe.param_count() * 2048
    assert est < 0.5 * tot
    assert est > 0.5 * act


def test_step_flops_train_is_3x_forward():
    cfg = get_config("minicpm_2b")
    assert step_flops(cfg, "train_4k") == pytest.approx(
        3 * forward_flops(cfg, 256, 4096), rel=1e-9)


def test_roofline_terms_structure():
    cfg = get_config("qwen1_5_32b")
    rt = roofline_terms(cfg, "train_4k", {"data": 16, "model": 16})
    assert rt["n_chips"] == 256
    assert rt["bottleneck"] in ("compute", "memory", "collective")
    assert rt["t_step_lower_bound"] == max(rt["t_compute"], rt["t_memory"],
                                           rt["t_collective"])
    for k in ("t_compute", "t_memory", "t_collective"):
        assert rt[k] > 0


def test_decode_memory_bound():
    """Single-token decode must be memory-bound (weights read per token)."""
    cfg = get_config("qwen1_5_32b")
    rt = roofline_terms(cfg, "decode_32k", {"data": 16, "model": 16})
    assert rt["t_memory"] > rt["t_compute"]


def test_simulator_mode_ordering():
    cfg = get_config("qwen2_5_7b")
    w = Workload(prompts_per_step=128, group_size=8, num_steps=4)
    plan = ClusterPlan(256, 128, 128, 4, 8)
    r = {m: simulate(cfg, plan, w, m)["throughput_samples_per_s"]
         for m in ("separated", "separated_tq", "separated_async")}
    assert r["separated"] < r["separated_tq"] < r["separated_async"]


def test_simulator_scaling_improves_asyncflow_ratio():
    """The paper's headline: AsyncFlow's advantage over the colocated
    baseline grows with cluster size (Fig. 10)."""
    cfg = get_config("qwen2_5_7b")
    w = Workload(prompts_per_step=256, group_size=8, num_steps=4)
    ratios = []
    for n in (64, 256, 1024):
        plan = plan_resources(cfg, n, w).plan
        af = simulate(cfg, plan, w, "separated_async")
        verl = simulate(cfg, ClusterPlan(n, n, n, 4, 8,
                                         reshard_s=1.0 + 0.002 * n),
                        w, "colocated")
        ratios.append(af["throughput_samples_per_s"]
                      / verl["throughput_samples_per_s"])
    assert ratios[0] < ratios[-1]
    assert ratios[-1] > 1.2


def test_plan_resources_valid_split():
    cfg = get_config("qwen2_5_7b")
    w = Workload(prompts_per_step=64, group_size=4, num_steps=2)
    pr = plan_resources(cfg, 128, w)
    p = pr.plan
    assert p.rollout_chips + p.train_chips == 128
    assert p.rollout_chips % p.rollout_tp == 0
    assert pr.throughput > 0
    assert pr.candidates_scored == len(candidate_plans(128))


def test_hybrid_cost_model_profiling_path():
    cfg = get_config("qwen2_5_7b")
    w = Workload(prompts_per_step=64, group_size=4, num_steps=2)
    calls = []

    def profile_fn(plan):
        calls.append(plan)
        return {"decode_token_s": 0.001}

    pr = plan_resources(cfg, 128, w, profile_fn=profile_fn, profile_top_k=2)
    assert len(calls) == 2
    assert pr.throughput > 0


def test_profiling_hybrid_path_end_to_end():
    """§4.3 hybrid: measure reduced blocks on CPU, extrapolate, re-rank."""
    from repro.core.planner.profiling import make_profile_fn
    cfg = get_config("qwen2_5_7b")
    w = Workload(prompts_per_step=64, group_size=4, num_steps=2)
    pf = make_profile_fn(cfg, w)
    assert pf.raw["reduced_decode_s"] > 0
    assert pf.raw["reduced_train_s"] > 0
    pr = plan_resources(cfg, 128, w, profile_fn=pf, profile_top_k=2)
    assert pr.throughput > 0


# ---------------------------------------------------------------------- #
# elastic stage sizing: analytic stage costs -> worker counts -> live      #
# rebalance from obs starvation signals                                    #
# ---------------------------------------------------------------------- #

def _grpo_graph_and_engines():
    from types import SimpleNamespace

    from repro.core.workflow import build_dataflow
    cfg = get_config("qwen2_5_7b")
    g = build_dataflow("grpo", kl_coef=0.05)
    eng = SimpleNamespace(cfg=cfg, group_size=8, max_new_tokens=512)
    return g, {"rollout": eng, "actor": eng}


def test_estimate_stage_costs_sources_and_ordering():
    from repro.core.planner import estimate_stage_costs
    g, engines = _grpo_graph_and_engines()
    costs = estimate_stage_costs(g, engines, seq_len=1024, group_size=8,
                                 profiled={"reward": 0.5})
    assert set(costs) == set(g.stages)
    assert costs["reward"].source == "profiled"
    assert costs["reward"].seconds_per_row == 0.5
    assert costs["generate"].source == "analytic"
    # decode-dominated generation costs more per row than one forward pass
    assert costs["generate"].seconds_per_row \
        > costs["ref_inference"].seconds_per_row
    # engine verbs without a forward pass are priced at the cheap default
    costs2 = estimate_stage_costs(g, engines, seq_len=1024, group_size=8)
    assert costs2["reward"].seconds_per_row < 1e-3


def test_auto_size_workers_matches_driver_rate():
    from repro.core.planner import auto_size_workers, estimate_stage_costs
    g, engines = _grpo_graph_and_engines()
    costs = estimate_stage_costs(g, engines, seq_len=1024, group_size=8)
    sizes = auto_size_workers(g, costs, max_workers=8)
    assert sizes["actor_update"] == 1          # step driver single-threaded
    assert all(1 <= n <= 8 for n in sizes.values())
    # generation is the expensive stage: it must get the most workers
    assert sizes["generate"] > 1
    assert sizes["generate"] == max(sizes.values())


def test_auto_sized_pipeline_beats_starved_hand_tuning():
    """Acceptance: planner-sized counts beat a deliberately starved
    hand-tuned config (one worker everywhere) in the pipeline simulator."""
    from repro.core.planner import (auto_size_workers, estimate_stage_costs,
                                    simulate_stage_pipeline)
    g, engines = _grpo_graph_and_engines()
    costs = estimate_stage_costs(g, engines, seq_len=1024, group_size=8)
    sized = auto_size_workers(g, costs, max_workers=8)
    starved = {n: 1 for n in costs}
    t_sized = simulate_stage_pipeline(costs, sized, n_rows=1024)
    t_starved = simulate_stage_pipeline(costs, starved, n_rows=1024)
    assert t_sized < t_starved


def test_elastic_controller_grows_producers_then_shrinks():
    from repro.core.obs import MetricsRegistry
    from repro.core.planner import ElasticController
    from repro.core.workflow import StageGraph, StageSpec

    g = StageGraph(source_columns=("prompt",))
    g.add(StageSpec("generate", inputs=("prompt",), outputs=("item",),
                    kind="generate"))
    g.add(StageSpec("enrich", inputs=("item",), outputs=("score",)))
    g.add(StageSpec("actor_update", inputs=("item", "score"), kind="train",
                    drives_steps=True))
    g.validate()

    m = MetricsRegistry()
    stalls = m.counter("stage_stalls_total", "")
    waits = m.counter("tq_blocked_wait_seconds_total", "")
    m.histogram("stage_batch_seconds", "")
    desired = {"generate": 1, "enrich": 1, "actor_update": 1}
    calls = []

    def apply(name, delta):
        calls.append((name, delta))
        desired[name] += delta
        return True

    ec = ElasticController(g, m, desired, apply, patience=2, max_workers=4)
    ec.step()                                   # baseline interval: no-op
    assert calls == []

    # the blocking driver starves: blocked-wait grows past the threshold
    # for `patience` consecutive intervals -> both input producers grow
    for _ in range(2):
        waits.inc(0.2, task="actor_update", consumer="trainer")
        ec.step()
    assert ("generate", 1) in calls and ("enrich", 1) in calls
    reb = m.counter("stage_rebalance_total", "")
    assert reb.value(stage="generate", action="grow") == 1

    # a polling stage starves while its producer is at the cap -> the
    # starved (idle) pool itself shrinks back toward one worker
    calls.clear()
    desired["generate"] = 4
    for _ in range(2):
        stalls.inc(5, stage="enrich")
        ec.step()
    assert calls == [("enrich", -1)]
    assert reb.value(stage="enrich", action="shrink") == 1
    # the driver is never resized
    assert all(name != "actor_update" for name, _ in calls)
