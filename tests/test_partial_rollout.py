"""Partial rollout (k1.5-style, paper §4.2.1): chunked generation with
continuation requeue through TransferQueue."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import PromptDataset
from repro.data.tokenizer import ByteTokenizer
from repro.engines import JaxRolloutEngine, JaxTrainEngine
from repro.core.workflow import AsyncRLRunner, WorkflowConfig
from repro.models import forward, init_params
from repro.rl.loss import token_logprobs


def _cfg():
    return dataclasses.replace(
        get_config("qwen2_5_7b").reduced(), num_layers=2, d_model=64,
        d_ff=128, num_heads=2, num_kv_heads=2, head_dim=32,
        vocab_size=ByteTokenizer.vocab_size)


def test_chunked_generation_semantics():
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = JaxRolloutEngine(cfg, group_size=2, max_new_tokens=6,
                           chunk_tokens=2)
    rng = np.random.default_rng(0)
    prompts = PromptDataset(seed=0).prompts_for_step(0, 2)

    rows, conts = eng.generate_chunked(params, prompts, rng, version=0)
    # 2 prompts x G=2 members, each advanced by <=2 tokens
    assert len(rows) + len(conts) <= 4 or len(rows) % 2 == 0
    for c in conts:
        assert c["gen_len"] <= 2
        assert c["versions"] == [0]

    # keep resuming until every group finishes
    all_rows = list(rows)
    for it in range(1, 6):
        if not conts:
            break
        rows, conts = eng.generate_chunked(params, conts, rng, version=it)
        all_rows.extend(rows)
    assert not conts
    assert len(all_rows) == 4            # 2 prompts x G=2
    for r in all_rows:
        assert r["token_len"] <= 6
        assert len(r["chunk_versions"]) >= 1
        assert r["response_mask"].sum() == r["token_len"]


def test_chunked_logprobs_match_forward_single_version():
    """If no weight update happens between chunks, the spliced behavior
    logprobs must equal the full-forward logprobs (ratio == 1)."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = JaxRolloutEngine(cfg, group_size=2, max_new_tokens=6,
                           chunk_tokens=2)
    rng = np.random.default_rng(1)
    prompts = PromptDataset(seed=1).prompts_for_step(0, 1)
    rows, conts = eng.generate_chunked(params, prompts, rng)
    all_rows = list(rows)
    while conts:
        rows, conts = eng.generate_chunked(params, conts, rng)
        all_rows.extend(rows)
    for r in all_rows:
        toks = jnp.asarray(r["response"][None, :])
        logits, _ = forward(params, cfg, {"tokens": toks})
        lp, _ = token_logprobs(logits[:, :-1], toks[:, 1:])
        mask = r["response_mask"][1:]
        diff = np.abs(np.asarray(lp)[0] - r["logprob"][1:]) * mask
        assert diff.max() < 0.05, diff.max()


def test_partial_rollout_through_workflow():
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rollout = JaxRolloutEngine(cfg, group_size=2, max_new_tokens=6,
                               chunk_tokens=2)
    trainer = JaxTrainEngine(cfg, params, global_batch=8, seq_len=24)
    ds = PromptDataset(seed=0)
    wcfg = WorkflowConfig(mode="async", num_rollout_workers=2,
                          rollout_batch=2, train_micro_batch=4,
                          prompts_per_step=4, group_size=2, num_steps=2)
    r = AsyncRLRunner(wcfg, rollout_engine=rollout, train_engine=trainer,
                      prompt_stream=lambda s: ds.prompts_for_step(s, 4)).run()
    assert r.samples_trained == 16
    assert len(r.metrics) == 2
    assert max(r.staleness_seen) <= 2
