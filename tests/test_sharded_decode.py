"""Distributed flash-decode (HC3 production path): subprocess check on 8
fake devices — partial-softmax shard combine matches the single-device
oracle, with O(B·H·hd) combine collectives."""
import os
import subprocess
import sys


def test_sharded_flash_decode_subprocess():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, os.path.join(root, "scripts",
                                      "sharded_decode_check.py")],
        capture_output=True, text=True, timeout=600, cwd=root)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-1000:])
    assert "sharded flash-decode OK" in r.stdout
