"""Explicit all_to_all expert-parallel MoE: subprocess check on an
8-device (2 data x 4 model) mesh — must match the single-device MoE
oracle exactly on drop-free shapes, with explicit all-to-all ops in HLO."""
import os
import subprocess
import sys


def test_ep_moe_all_to_all_subprocess():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, os.path.join(root, "scripts", "ep_moe_check.py")],
        capture_output=True, text=True, timeout=600, cwd=root)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-1500:])
    assert "EP MoE all_to_all OK" in r.stdout
    assert "all-to-all ops in HLO" in r.stdout
