"""Optional-hypothesis shim: property tests run when ``hypothesis`` is
installed (see requirements-dev.txt) and skip cleanly when it is not —
the tier-1 suite must collect on a bare runtime image.

Usage in test modules::

    from _hyp import given, settings, st
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                   # pragma: no cover
    import pytest

    def given(*_a, **_k):
        def deco(f):
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped
        return deco

    def settings(*_a, **_k):
        def deco(f):
            return f
        return deco

    class _Strategies:
        """Stand-in for ``hypothesis.strategies``: strategy constructors
        are evaluated at decoration time, so they must exist but their
        results are never used."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
