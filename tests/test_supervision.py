"""Supervised generator fleet: error taxonomy + deterministic retry,
config-driven fault injection, replica supervision (heartbeats, fencing,
respawn, restart budget), TransferQueue lease/ack/requeue, one-to-many
weight broadcast with per-replica acks, and chaos runs through the full
StageRunner (exactly-once recovery under injected crashes)."""
import threading
import time
from types import SimpleNamespace

import pytest

from repro.core.obs import MetricsRegistry
from repro.core.supervision import (FaultConfig, FaultInjector, ReplicaCrash,
                                    ReplicaSupervisor, RetryPolicy,
                                    RetryableError, SupervisionExhausted,
                                    TransientStageError, WeightSyncTimeout,
                                    call_with_retry, is_retryable,
                                    register_retryable)
from repro.core.transfer_queue import TransferQueue
from repro.core.workflow import (StageGraph, StageRunner, StageSpec,
                                 WorkflowConfig)
from repro.core.workflow.weight_sync import (BroadcastWeightChannel,
                                             VersionedWeights, WeightChannel,
                                             WeightReceiver, WeightSender)


# ---------------------------------------------------------------------- #
# error taxonomy                                                          #
# ---------------------------------------------------------------------- #

def test_taxonomy_retryable_vs_fatal():
    assert is_retryable(RetryableError("x"))
    assert is_retryable(TransientStageError("x"))
    assert not is_retryable(ReplicaCrash("x"))       # fleet-level, not retry
    assert not is_retryable(WeightSyncTimeout(3, 1, 2.0))
    assert not is_retryable(ValueError("x"))


def test_register_external_retryable():
    class FlakyBackend(Exception):
        pass

    assert not is_retryable(FlakyBackend("x"))
    register_retryable(FlakyBackend)
    assert is_retryable(FlakyBackend("x"))


# ---------------------------------------------------------------------- #
# deterministic retry                                                     #
# ---------------------------------------------------------------------- #

def test_retry_backoff_bounded_and_deterministic():
    p = RetryPolicy(max_attempts=5, base_s=0.1, multiplier=2.0,
                    max_backoff_s=0.5, jitter=0.5, seed=3)
    seq = [p.backoff_s(k, key="gen:0") for k in range(5)]
    assert seq == [p.backoff_s(k, key="gen:0") for k in range(5)]  # determ.
    for k, b in enumerate(seq):
        cap = min(0.1 * 2.0 ** k, 0.5)
        assert 0.5 * cap <= b <= cap            # jitter scales in [1-j, 1)
    # a different key draws a different jitter stream
    assert seq != [p.backoff_s(k, key="gen:1") for k in range(5)]


def test_call_with_retry_recovers_then_exhausts():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientStageError("transient")
        return "ok"

    retried = []
    out = call_with_retry(flaky, policy=RetryPolicy(max_attempts=3,
                                                    base_s=0.0),
                          on_retry=lambda a, e: retried.append(a),
                          sleep=lambda s: None)
    assert out == "ok" and calls["n"] == 3 and len(retried) == 2

    calls["n"] = -10                            # always transient now
    with pytest.raises(TransientStageError):
        call_with_retry(flaky, policy=RetryPolicy(max_attempts=2,
                                                  base_s=0.0),
                        sleep=lambda s: None)


def test_call_with_retry_fatal_not_retried():
    calls = {"n": 0}

    def fatal():
        calls["n"] += 1
        raise ValueError("fatal")

    with pytest.raises(ValueError):
        call_with_retry(fatal, policy=RetryPolicy(max_attempts=4,
                                                  base_s=0.0),
                        sleep=lambda s: None)
    assert calls["n"] == 1


# ---------------------------------------------------------------------- #
# fault injection                                                         #
# ---------------------------------------------------------------------- #

def _fault_trace(cfg: FaultConfig, n: int = 32, stage: str = "generate",
                 worker: int = 0):
    inj = FaultInjector(cfg, metrics=MetricsRegistry(), sleep=lambda s: None)
    trace = []
    for _ in range(n):
        try:
            inj.check(stage, worker)
            trace.append("ok")
        except ReplicaCrash:
            trace.append("crash")
        except TransientStageError:
            trace.append("error")
    return trace


def test_fault_injector_deterministic_by_seed():
    cfg = FaultConfig(crash_p=0.2, error_p=0.2, seed=7)
    t1 = _fault_trace(cfg)
    assert t1 == _fault_trace(cfg)              # same seed -> same faults
    assert t1 != _fault_trace(FaultConfig(crash_p=0.2, error_p=0.2, seed=8))
    assert "crash" in t1 and "error" in t1


def test_fault_injector_stage_filter_and_crash_cap():
    cfg = FaultConfig(crash_p=1.0, stages=("generate",), max_crashes=2)
    assert _fault_trace(cfg, n=8, stage="reward") == ["ok"] * 8
    t = _fault_trace(cfg, n=8, stage="generate")
    assert t == ["crash", "crash"] + ["ok"] * 6  # cap stops the injector


# ---------------------------------------------------------------------- #
# replica supervisor                                                      #
# ---------------------------------------------------------------------- #

def _supervisor(**kw):
    log = SimpleNamespace(respawned=[], requeued=[], exhausted=[])
    sup = ReplicaSupervisor(
        lambda dead: (log.respawned.append(dead.rid), True)[1],
        requeue=lambda dead: (log.requeued.append(dead.rid), 1)[1],
        on_exhausted=log.exhausted.append,
        heartbeat_timeout_s=kw.pop("heartbeat_timeout_s", 0.0),
        metrics=MetricsRegistry(), **kw)
    return sup, log


def test_supervisor_reported_crash_requeues_then_respawns():
    sup, log = _supervisor()
    h = sup.register(0, None)
    sup.report_death(0, "injected")
    assert h.fenced                             # zombie writes are blocked
    assert sup.poll() == 1
    assert log.requeued == [0] and log.respawned == [0]
    assert sup.poll() == 0                      # recovery is collect-once
    assert sup.restarts == 1 and sup.deaths == 1


def test_supervisor_detects_dead_thread_and_stale_heartbeat():
    sup, log = _supervisor(heartbeat_timeout_s=0.05)
    t = threading.Thread(target=lambda: None)
    t.start()
    t.join()
    sup.register(0, t)                          # thread already exited
    h1 = sup.register(1, threading.current_thread())
    h1.last_beat -= 1.0                         # stale heartbeat (hung)
    assert sup.poll() == 2
    assert sorted(log.respawned) == [0, 1]
    assert h1.fenced and "heartbeat" in h1.reason


def test_supervisor_budget_exhaustion_fails_loudly():
    sup, log = _supervisor(max_restarts=1)
    sup.register(0, None)
    sup.register(1, None)
    sup.report_death(0, "first")
    sup.poll()
    sup.report_death(1, "second")               # budget already spent
    assert sup.poll() == 0
    assert len(log.exhausted) == 1
    assert isinstance(log.exhausted[0], SupervisionExhausted)
    assert log.requeued == [0, 1]               # rows still recovered


def test_supervisor_retired_replica_not_respawned():
    sup, log = _supervisor()
    sup.register(0, None)
    sup.retire(0)                               # clean drain/shrink exit
    assert sup.poll() == 0 and not log.respawned


# ---------------------------------------------------------------------- #
# TransferQueue lease / ack / requeue                                     #
# ---------------------------------------------------------------------- #

def _leased_queue(n=6):
    tq = TransferQueue(capacity=16, tasks={"gen": ["prompt"]},
                       num_storage_units=1, metrics=MetricsRegistry())
    idxs = tq.next_indices(n)
    tq.put_batch(idxs, "prompt", [f"p{i}" for i in range(n)])
    return tq


def test_lease_requeue_restores_fifo_front_order():
    tq = _leased_queue()
    b1 = tq.get("gen", 2, consumer="w0", lease=True)
    b2 = tq.get("gen", 2, consumer="w1", lease=True)
    assert b1["indices"] == [0, 1] and b2["indices"] == [2, 3]
    # w0 dies: its rows return to the FRONT, ahead of still-ready row 4/5
    assert tq.requeue("gen", b1["lease"]) == 2
    b3 = tq.get("gen", 4, consumer="w1", lease=True)
    assert b3["indices"] == [0, 1, 4, 5]        # recovered order preserved
    # requeue is idempotent; acked leases can never requeue
    assert tq.requeue("gen", b1["lease"]) == 0
    tq.ack("gen", b2["lease"])
    assert tq.requeue("gen", b2["lease"]) == 0
    reg = tq.controllers["gen"].metrics
    assert reg.get("rows_requeued_total").value(task="gen") == 2


def test_requeue_consumer_returns_all_outstanding_leases():
    tq = _leased_queue()
    tq.get("gen", 2, consumer="w0", lease=True)
    tq.get("gen", 2, consumer="w0", lease=True)
    tq.get("gen", 2, consumer="w1", lease=True)
    assert tq.controllers["gen"].outstanding_leases("w0") == 2
    assert tq.requeue_consumer("gen", "w0") == 4
    assert tq.controllers["gen"].outstanding_leases("w0") == 0
    assert tq.controllers["gen"].outstanding_leases("w1") == 1


def test_unleased_get_unchanged():
    tq = _leased_queue()
    b = tq.get("gen", 2, consumer="w0")
    assert "lease" not in b
    assert tq.controllers["gen"].outstanding_leases() == 0


def test_double_ack_is_noop():
    tq = _leased_queue()
    b = tq.get("gen", 2, consumer="w0", lease=True)
    tq.ack("gen", b["lease"])
    tq.ack("gen", b["lease"])                    # second ack: silent no-op
    assert tq.requeue("gen", b["lease"]) == 0    # acked lease never requeues
    assert tq.controllers["gen"].outstanding_leases() == 0
    assert tq.controllers["gen"].state_snapshot()["ready"] == 4


def test_requeue_consumer_racing_ack_exactly_once():
    """requeue_consumer (supervisor noticing a dead trainer) racing a
    concurrent ack (the trainer's last snapshot commit): the lease is
    popped atomically, so the rows are either finalized or requeued —
    never both, never lost."""
    for trial in range(25):
        tq = _leased_queue()
        b = tq.get("gen", 2, consumer="t0", lease=True)
        n = {"requeued": None}

        def _rq():
            n["requeued"] = tq.requeue_consumer("gen", "t0")

        threads = [threading.Thread(target=lambda: tq.ack("gen", b["lease"])),
                   threading.Thread(target=_rq)]
        if trial % 2:                            # alternate start order
            threads.reverse()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        ready = tq.controllers["gen"].state_snapshot()["ready"]
        # ack won -> rows stay consumed (4 ready); requeue won -> rows
        # return to the front (6 ready). Exactly one of the two.
        assert (n["requeued"], ready) in ((0, 4), (2, 6))
        assert tq.controllers["gen"].outstanding_leases("t0") == 0


def test_requeue_after_close_task_still_drains():
    """A trainer crash after the feed closed the task: requeued rows must
    still be fetchable (closed means no NEW rows, not dropped rows)."""
    tq = _leased_queue()
    b = tq.get("gen", 2, consumer="t0", lease=True)
    tq.close_task("gen")
    assert tq.requeue("gen", b["lease"]) == 2
    got = tq.get("gen", 6, consumer="t1", allow_partial=True)
    assert got["indices"] == [0, 1, 2, 3, 4, 5]  # front order, none lost
    assert tq.get("gen", 2, consumer="t1", timeout=0.1) is None  # drained


def test_requeue_consumer_multi_lease_restores_issue_order():
    """A consumer holding several leases at once (the checkpointing
    trainer acks only at snapshot boundaries) gets its rows back in the
    original issue order: newest-first requeue composes with front
    insertion so replayed training sees the identical schedule."""
    tq = _leased_queue()
    batches = [tq.get("gen", 2, consumer="t0", lease=True) for _ in range(3)]
    assert [b["indices"] for b in batches] == [[0, 1], [2, 3], [4, 5]]
    assert tq.requeue_consumer("gen", "t0") == 6
    refetch = [tq.get("gen", 2, consumer="t0", lease=True)["indices"]
               for _ in range(3)]
    assert refetch == [[0, 1], [2, 3], [4, 5]]


# ---------------------------------------------------------------------- #
# one-to-many weight broadcast                                            #
# ---------------------------------------------------------------------- #

def test_broadcast_publishes_once_for_n_receivers():
    import numpy as np
    reg = MetricsRegistry()
    ch = BroadcastWeightChannel(metrics=reg)
    sender = WeightSender(ch, mode="sync", metrics=reg)
    params = {"w": np.ones((8, 8), np.float32)}
    recvs = [WeightReceiver(ch, params, metrics=reg, replica_id=i)
             for i in range(4)]
    assert ch.num_subscribers() == 4
    sender.publish(params, 1)
    # bytes on the channel are independent of fleet size (one snapshot)
    assert reg.get("weight_bytes_published_total").value() == 8 * 8 * 4
    for r in recvs:
        assert r.maybe_swap()
    # ... and every receiver swapped the SAME host buffer (by reference)
    hosts = {id(ch.peek().host_params)}
    assert len(hosts) == 1
    assert ch.acked_versions() == {0: 1, 1: 1, 2: 1, 3: 1}
    assert ch.min_acked() == 1
    assert reg.get("weight_broadcast_seconds").snapshot()[0]["count"] == 1


def test_broadcast_min_acked_tracks_lagging_replica():
    import numpy as np
    ch = BroadcastWeightChannel(metrics=MetricsRegistry())
    params = {"w": np.zeros(2, np.float32)}
    fast = WeightReceiver(ch, params, metrics=MetricsRegistry(),
                          replica_id=0)
    slow = WeightReceiver(ch, params, metrics=MetricsRegistry(),
                          replica_id=1)
    ch.offer(VersionedWeights(3, params))
    fast.maybe_swap()
    assert ch.min_acked() == 0                  # slow replica still at 0
    slow.maybe_swap()
    assert ch.min_acked() == 3
    ch.unsubscribe(1)                           # dead replica leaves the
    ch.offer(VersionedWeights(4, params))       # staleness floor
    fast.maybe_swap()
    assert ch.acked_versions() == {0: 4}


# ---------------------------------------------------------------------- #
# weight-sync timeout (satellite: informative, never a silent no-op)      #
# ---------------------------------------------------------------------- #

def test_wait_for_timeout_names_versions():
    ch = WeightChannel(metrics=MetricsRegistry())
    ch.offer(VersionedWeights(2, {"w": 1}))
    with pytest.raises(WeightSyncTimeout) as ei:
        ch.wait_for(5, timeout=0.01, strict=True)
    err = ei.value
    assert err.waited_for == 5 and err.latest_seen == 2
    assert "version >= 5" in str(err) and "latest version seen: 2" in str(err)
    # non-strict callers keep the legacy poll-style None
    assert ch.wait_for(5, timeout=0.01) is None


def test_wait_and_swap_timeout_raises_by_default():
    ch = WeightChannel(metrics=MetricsRegistry())
    recv = WeightReceiver(ch, {"w": 0}, metrics=MetricsRegistry())
    with pytest.raises(WeightSyncTimeout) as ei:
        recv.wait_and_swap(3, timeout=0.01)
    assert ei.value.waited_for == 3 and ei.value.latest_seen == -1
    assert recv.wait_and_swap(3, timeout=0.01, strict=False) is False
    assert recv.version == 0                    # timeout never fake-swaps


# ---------------------------------------------------------------------- #
# StageRunner error attribution (satellite: first failure wins)           #
# ---------------------------------------------------------------------- #

def _toy_graph(gen_fn=None, enrich_fn=None):
    def gen(batch, *, params, rng, version=0, **kw):
        return {"rows": [dict(item=x, token_len=1)
                         for x in batch["prompt"] for _ in range(2)]}

    def enrich(batch, *, indices, **kw):
        return {"updates": {"score": [v + 1 for v in batch["item"]]}}

    def train(batch, **kw):
        return {"n": len(batch["version"])}

    g = StageGraph(source_columns=("prompt",))
    g.add(StageSpec("generate", inputs=("prompt",),
                    outputs=("item", "version"), fn=gen_fn or gen,
                    kind="generate"))
    g.add(StageSpec("enrich", inputs=("item",), outputs=("score",),
                    fn=enrich_fn or enrich))
    g.add(StageSpec("actor_update", inputs=("item", "score", "version"),
                    engine="trainer", fn=train, kind="train",
                    drives_steps=True))
    return g


def _runner(graph, metrics=None, **cfg_kw):
    cfg_kw.setdefault("mode", "streaming")
    cfg_kw.setdefault("num_rollout_workers", 2)
    cfg_kw.setdefault("rollout_batch", 2)
    cfg_kw.setdefault("train_micro_batch", 4)
    cfg_kw.setdefault("prompts_per_step", 4)
    cfg_kw.setdefault("group_size", 2)
    cfg_kw.setdefault("num_steps", 3)
    return StageRunner(
        WorkflowConfig(**cfg_kw), graph,
        engines={"trainer": SimpleNamespace(params={"w": 0})},
        prompt_stream=lambda s: [1, 2, 3, 4],
        metrics=metrics or MetricsRegistry())


def test_fail_names_stage_and_worker_and_keeps_first():
    def bad_enrich(batch, *, indices, **kw):
        raise KeyError("enrich exploded")

    runner = _runner(_toy_graph(enrich_fn=bad_enrich))
    with pytest.raises(RuntimeError, match=r"stage 'enrich' worker 0.*"
                                           r"enrich exploded"):
        runner.run()
    assert runner._error_origin == ("enrich", 0)


def test_fail_first_failure_wins_when_workers_race():
    runner = _runner(_toy_graph())
    runner._fail("generate", 1, ValueError("root cause"))
    runner._fail("enrich", 0, ValueError("victim of the stop"))
    assert runner._error_origin == ("generate", 1)
    assert "root cause" in runner._error


# ---------------------------------------------------------------------- #
# chaos through the full StageRunner                                      #
# ---------------------------------------------------------------------- #

def test_supervised_run_recovers_from_injected_crashes():
    """Crashes on supervised generate replicas must not lose or duplicate
    a single row: leases requeue at the front, replicas respawn, and the
    trained totals match a fault-free run exactly."""
    reg = MetricsRegistry()
    # seed 8 crashes worker 0 on its first call (and worker 1 soon after)
    runner = _runner(_toy_graph(), metrics=reg,
                     faults=FaultConfig(crash_p=0.05, seed=8,
                                        stages=("generate",)),
                     heartbeat_timeout_s=30.0, max_replica_restarts=16)
    r = runner.run()
    assert r.samples_trained == 3 * 8           # zero lost rows
    assert reg.get("stage_samples_total").value(stage="generate") == 3 * 8
    assert reg.get("replica_restarts_total").value(stage="generate") >= 1
    assert reg.get("rows_requeued_total").value(task="generate") >= 1
    assert reg.get("faults_injected_total").value(
        stage="generate", kind="crash") >= 1
    # recovered replicas subscribed to the broadcast under fresh ids
    assert runner.channel.num_subscribers() >= 2
    assert runner._supervisor.restarts == runner._supervisor.deaths


def test_supervised_async_run_with_crashes_and_transients():
    """Async mode under combined crash + transient-error injection:
    transients retry in place (stage_retries_total), crashes recover
    through the fleet, totals stay exact."""
    reg = MetricsRegistry()
    runner = _runner(_toy_graph(), metrics=reg, mode="async", staleness=1,
                     faults=FaultConfig(crash_p=0.05, error_p=0.3, seed=8,
                                        stages=("generate",)),
                     heartbeat_timeout_s=30.0, max_replica_restarts=16,
                     max_stage_retries=4)
    r = runner.run()
    assert r.samples_trained == 3 * 8
    assert reg.get("stage_retries_total").value(stage="generate") >= 1
    assert reg.get("replica_restarts_total").value(stage="generate") >= 1


def test_restart_budget_exhaustion_fails_the_run():
    reg = MetricsRegistry()
    runner = _runner(_toy_graph(), metrics=reg,
                     faults=FaultConfig(crash_p=1.0, seed=0,
                                        stages=("generate",)),
                     heartbeat_timeout_s=30.0, max_replica_restarts=2)
    with pytest.raises(RuntimeError, match="restart budget"):
        runner.run()


def test_unsupervised_crash_is_fatal_with_attribution():
    runner = _runner(_toy_graph(), supervise=False,
                     faults=FaultConfig(crash_p=1.0, seed=0,
                                        stages=("generate",)))
    with pytest.raises(RuntimeError, match=r"stage 'generate' worker \d"):
        runner.run()


def test_supervision_summary_line_in_report():
    from repro.core.obs import render_report
    reg = MetricsRegistry()
    runner = _runner(_toy_graph(), metrics=reg,
                     faults=FaultConfig(crash_p=0.05, seed=8,
                                        stages=("generate",)),
                     heartbeat_timeout_s=30.0, max_replica_restarts=16)
    r = runner.run()
    report = render_report(r.telemetry)
    assert "supervision:" in report
    assert "replica restarts" in report and "rows requeued" in report
