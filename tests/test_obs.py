"""Unified telemetry layer: registry thread-safety, histogram quantiles,
Chrome-trace export, EventLog bubble accounting fixes, gantt symbol
stability, the JSONL sampler, the benchmark trajectory recorder, and a
staged GRPO smoke run populating queue/staleness metrics."""
import json
import os
import subprocess
import sys
import threading
import time
from types import SimpleNamespace

import pytest

from repro.core.obs import (MetricsRegistry, build_telemetry, get_registry,
                            render_report, scoped)
from repro.core.workflow import StageGraph, StageRunner, StageSpec, \
    WorkflowConfig
from repro.core.workflow.events import EventLog

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------- #
# registry                                                                #
# ---------------------------------------------------------------------- #

def test_counter_thread_safety_under_concurrent_increments():
    reg = MetricsRegistry()
    c = reg.counter("hits", "test")
    bound = c.labels(stage="s")
    n_threads, per_thread = 8, 5000

    def worker():
        for _ in range(per_thread):
            bound.inc()
            c.inc(1, other="t")

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value(stage="s") == n_threads * per_thread
    assert c.value(other="t") == n_threads * per_thread
    assert c.total() == 2 * n_threads * per_thread


def test_histogram_concurrent_observe_exact_count_and_sum():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "test")

    def worker(k):
        b = h.labels(stage="s")
        for i in range(1000):
            b.observe(1.0)

    threads = [threading.Thread(target=worker, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    s = h.summary(stage="s")
    assert s["count"] == 4000
    assert s["sum"] == pytest.approx(4000.0)


def test_histogram_quantiles():
    reg = MetricsRegistry()
    h = reg.histogram("q", "test")
    for v in range(1, 101):           # 1..100
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 100
    assert s["min"] == 1.0 and s["max"] == 100.0
    assert s["mean"] == pytest.approx(50.5)
    assert s["p50"] == pytest.approx(50.5)
    assert s["p95"] == pytest.approx(95.05)
    assert s["p99"] == pytest.approx(99.01)


def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("x", "help")
    assert reg.counter("x") is a
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("x")
    snap = reg.snapshot()
    assert snap["x"]["type"] == "counter" and snap["x"]["help"] == "help"


def test_scoped_registry_isolates_the_global_default():
    outer = get_registry()
    with scoped() as reg:
        assert get_registry() is reg
        get_registry().counter("scoped_only").inc()
        assert reg.counter("scoped_only").total() == 1
    assert get_registry() is outer
    assert outer.get("scoped_only") is None


def test_gauge_set_inc_dec():
    reg = MetricsRegistry()
    g = reg.gauge("depth")
    g.set(5, task="t")
    g.inc(2, task="t")
    g.dec(3, task="t")
    assert g.value(task="t") == 4
    g.labels(task="t").set(42)
    assert g.value(task="t") == 42


# ---------------------------------------------------------------------- #
# EventLog: chrome trace + overlap-merged bubble accounting + symbols     #
# ---------------------------------------------------------------------- #

def _log_with(events):
    log = EventLog()
    for inst, kind, s, e in events:
        log.record(inst, kind, log.t0 + s, log.t0 + e, n=1)
    return log


def test_chrome_trace_valid_json_monotonic_ts_dur():
    log = _log_with([("rollout-0", "generate", 0.0, 1.0),
                     ("rollout-0", "weight_sync", 1.0, 1.2),
                     ("train-0", "wait", 0.0, 0.9),
                     ("train-0", "update", 0.9, 1.4)])
    doc = json.loads(json.dumps(log.to_chrome_trace()))
    assert isinstance(doc["traceEvents"], list)
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(xs) == 4
    assert all(e["dur"] >= 0 for e in xs)
    assert all(a["ts"] <= b["ts"] for a, b in zip(xs, xs[1:]))
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "M"}
    assert "thread_name" in names
    # idle kinds are categorised separately so Perfetto can filter them
    assert {e["cat"] for e in xs} == {"stage", "idle"}


def test_chrome_trace_writes_file(tmp_path):
    log = _log_with([("a", "generate", 0.0, 0.5)])
    path = tmp_path / "trace.json"
    log.to_chrome_trace(path=str(path))
    doc = json.loads(path.read_text())
    assert doc["traceEvents"]


def test_busy_fraction_merges_overlapping_spans():
    # two workers recorded under ONE instance with overlapping spans:
    # [0,2] and [1,3] over a wall span of 3s — naive summing yields 4/3
    # busy (negative bubble); the union is exactly the wall span
    log = _log_with([("inst", "generate", 0.0, 2.0),
                     ("inst", "generate", 1.0, 3.0)])
    assert log.busy_fraction("inst") == pytest.approx(1.0)
    assert log.bubble_fraction()["inst"] == pytest.approx(0.0)


def test_busy_fraction_gap_still_counts_bubble():
    log = _log_with([("inst", "generate", 0.0, 1.0),
                     ("inst", "generate", 3.0, 4.0)])
    assert log.busy_fraction("inst") == pytest.approx(0.5)
    assert log.wait_fraction("inst") == pytest.approx(0.0)


def test_wait_fraction_counts_idle_kinds():
    log = _log_with([("inst", "generate", 0.0, 1.0),
                     ("inst", "wait", 1.0, 2.0)])
    assert log.busy_fraction("inst") == pytest.approx(0.5)
    assert log.wait_fraction("inst") == pytest.approx(0.5)


def test_render_gantt_stable_distinct_symbols_for_custom_stages():
    log = EventLog()
    log.register_kinds(["filter_stage", "score_stage"])
    log.record("w-0", "filter_stage", log.t0 + 0.0, log.t0 + 1.0)
    log.record("w-1", "score_stage", log.t0 + 1.0, log.t0 + 2.0)
    log.record("w-2", "generate", log.t0 + 0.0, log.t0 + 2.0)
    out = log.render_gantt(20)
    sym = log._symbols(log.events())
    assert sym["filter_stage"] != sym["score_stage"]
    assert "#" not in (sym["filter_stage"], sym["score_stage"])
    assert sym["generate"] == "G"
    # deterministic: registration order pins the assignment
    log2 = EventLog()
    log2.register_kinds(["filter_stage", "score_stage"])
    assert log2._symbols([]) == {**log2._symbols([]),
                                 "filter_stage": sym["filter_stage"],
                                 "score_stage": sym["score_stage"]}
    assert sym["filter_stage"] in out and sym["score_stage"] in out


# ---------------------------------------------------------------------- #
# sampler + stage-runner integration (no JAX: toy graph)                  #
# ---------------------------------------------------------------------- #

def _toy_graph():
    def gen(batch, *, params, rng, version=0, **kw):
        time.sleep(0.002)
        return {"rows": [dict(item=x, token_len=3)
                         for x in batch["prompt"] for _ in range(2)]}

    def train(batch, **kw):
        return {"n": len(batch["version"])}

    g = StageGraph(source_columns=("prompt",))
    g.add(StageSpec("generate", inputs=("prompt",),
                    outputs=("item", "version"), fn=gen, kind="generate"))
    g.add(StageSpec("actor_update", inputs=("item", "version"),
                    engine="trainer", fn=train, kind="train",
                    drives_steps=True))
    return g


def test_stage_runner_emits_jsonl_snapshots(tmp_path):
    path = tmp_path / "metrics.jsonl"
    with scoped() as reg:
        cfg = WorkflowConfig(mode="streaming", num_rollout_workers=1,
                             rollout_batch=2, train_micro_batch=4,
                             prompts_per_step=4, group_size=2, num_steps=2,
                             metrics_jsonl=str(path),
                             metrics_interval_s=0.02)
        r = StageRunner(cfg, _toy_graph(),
                        engines={"trainer": SimpleNamespace(params={"w": 0})},
                        prompt_stream=lambda s: [1, 2, 3, 4]).run()
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert lines, "sampler must emit at least a final snapshot"
    last = lines[-1]["metrics"]
    assert "tq_rows_consumed_total" in last
    assert "stage_batch_seconds" in last
    # telemetry table rides on the result
    assert r.telemetry["samples_trained"] == r.samples_trained
    assert any(row["stage"] == "generate" for row in r.telemetry["stages"])
    assert "generate" in render_report(r.telemetry)


def test_build_telemetry_shapes():
    log = _log_with([("rollout-0", "generate", 0.0, 1.0),
                     ("train-0", "update", 1.0, 2.0)])
    reg = MetricsRegistry()
    t = build_telemetry(log, reg, wall_time_s=2.0, samples_trained=8,
                        staleness_seen=[0, 1, 1, 2])
    assert t["throughput"] == pytest.approx(4.0)
    assert t["staleness"]["p50"] == pytest.approx(1.0)
    assert t["staleness"]["max"] == 2
    assert t["instances"]["rollout-0"]["busy_frac"] > 0
    assert isinstance(t["metrics"], dict)


# ---------------------------------------------------------------------- #
# staged GRPO smoke run populates the hot-layer metrics                   #
# ---------------------------------------------------------------------- #

def test_staged_grpo_populates_queue_and_staleness_metrics():
    from repro.api import Trainer, TrainerConfig
    with scoped() as reg:
        tcfg = TrainerConfig(mode="async", num_steps=2, prompts_per_step=2,
                             group_size=2, rollout_workers=2,
                             rollout_batch=1, train_micro_batch=2,
                             max_new_tokens=4, seq_len=24, kl_coef=0.05)
        r = Trainer(tcfg).fit()
        snap = reg.snapshot()
    # queue depth + consumption per task controller
    depth_tasks = {v["labels"]["task"]
                   for v in snap["tq_ready_depth"]["values"]}
    assert {"generate", "actor_update"} <= depth_tasks
    consumed = {v["labels"]["task"]: v["value"]
                for v in snap["tq_rows_consumed_total"]["values"]}
    assert consumed["actor_update"] == r.samples_trained
    # blocked-wait accounting per consumer exists
    assert snap["tq_blocked_wait_seconds_total"]["values"]
    # per-stage latency histograms with quantile summaries
    stages = {v["labels"]["stage"]: v
              for v in snap["stage_batch_seconds"]["values"]}
    assert "generate" in stages and "actor_update" in stages
    assert stages["generate"]["count"] > 0
    assert stages["generate"]["p95"] >= stages["generate"]["p50"] >= 0
    # staleness distribution observed at the train consumer
    stale = snap["train_staleness"]["values"][0]
    assert stale["count"] == len(r.staleness_seen) > 0
    assert stale["max"] <= tcfg.staleness + 1
    # tokens/samples throughput counters
    tokens = {v["labels"]["stage"]: v["value"]
              for v in snap["stage_tokens_total"]["values"]}
    assert tokens.get("generate", 0) > 0
    # weight path: bytes published + sync durations
    assert snap["weight_bytes_published_total"]["values"][0]["value"] > 0
    assert snap["weight_sync_seconds"]["values"]
    # the per-stage report renders and names the streamed stages
    rep = render_report(r.telemetry)
    assert "generate" in rep and "ref_inference" in rep
    assert r.telemetry["staleness"]["count"] == len(r.staleness_seen)


# ---------------------------------------------------------------------- #
# benchmark trajectory recorder                                           #
# ---------------------------------------------------------------------- #

def test_bench_run_json_trajectory(tmp_path):
    out = tmp_path / "BENCH_test.json"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO_ROOT, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--json", str(out),
         "roofline"],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr
    lines = proc.stdout.strip().splitlines()
    assert lines[0] == "name,us_per_call,derived"
    for ln in lines[1:]:               # strictly CSV: 3 fields, numeric time
        name, us, derived = ln.split(",", 2)
        float(us)
    doc = json.loads(out.read_text())
    assert doc["schema"] == "asyncflow-bench-trajectory/v1"
    assert doc["git_rev"]
    assert "roofline" in doc["suites"]
    assert doc["suites"]["roofline"]["error"] is None
    assert isinstance(doc["suites"]["roofline"]["rows"], list)


def test_bench_run_error_rows_keep_stdout_csv(monkeypatch, capsys, tmp_path):
    sys.path.insert(0, REPO_ROOT)
    try:
        import benchmarks.roofline as roofline
        import benchmarks.run as bench_run

        def boom():
            raise RuntimeError("suite exploded")

        monkeypatch.setattr(roofline, "run", boom)
        out = tmp_path / "BENCH_err.json"
        with pytest.raises(SystemExit) as exc:
            bench_run.main(["--json", str(out), "roofline"])
        assert exc.value.code == 1
        captured = capsys.readouterr()
        # stdout is strictly CSV — the ERROR row and traceback go to stderr
        assert captured.out.strip() == "name,us_per_call,derived"
        assert "roofline,ERROR,0" in captured.err
        assert "suite exploded" in captured.err
        # the trajectory file still records the failure, flushed before exit
        doc = json.loads(out.read_text())
        assert "suite exploded" in doc["suites"]["roofline"]["error"]
    finally:
        sys.path.remove(REPO_ROOT)
