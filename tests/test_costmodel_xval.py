"""Cross-validate the analytical cost model against XLA's cost_analysis.

With num_layers=1 the layer scan's while body executes exactly once, so
the CPU backend's per-instruction FLOP count is a sound total — the
analytic forward_flops must agree within 2x (fusion/masking slop) on a
single device.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.planner.cost_model import forward_flops
from repro.models import forward, init_params


def _xla_flops(cfg, B, S):
    params = jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    lowered = jax.jit(lambda p, b: forward(p, cfg, b)).lower(params, batch)
    cost = lowered.compile().cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return float(cost.get("flops", 0.0))


@pytest.mark.parametrize("arch", ["qwen2_5_7b", "minicpm3_4b"])
def test_forward_flops_match_xla_single_layer(arch):
    cfg = dataclasses.replace(
        get_config(arch).reduced(), num_layers=1, d_model=128, d_ff=256,
        num_heads=4, num_kv_heads=2 if arch == "qwen2_5_7b" else 4,
        head_dim=32, vocab_size=512)
    B, S = 2, 128
    got = _xla_flops(cfg, B, S)
    want = forward_flops(cfg, B, S)
    assert want / 2 <= got <= want * 2, (got, want)


def test_forward_flops_match_xla_ssm():
    cfg = dataclasses.replace(
        get_config("falcon_mamba_7b").reduced(), num_layers=1, d_model=128,
        vocab_size=512)
    B, S = 2, 128
    got = _xla_flops(cfg, B, S)
    want = forward_flops(cfg, B, S)
    # SSM scan lowers with extra elementwise work; allow 4x band
    assert want / 4 <= got <= want * 4, (got, want)
