"""Continuous-batching subsystem: slot scheduler + paged KV cache +
engine semantics (slot reuse, page accounting, EOS at boundaries,
admission fairness) and GRPO equivalence through the stage graph."""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core.obs import MetricsRegistry
from repro.data.tokenizer import ByteTokenizer
from repro.engines.continuous_batching import (ContinuousBatchingEngine,
                                               KVPoolExhausted, PagedKVPool,
                                               SlotScheduler)


def _cfg():
    return tiny_cfg()


@pytest.fixture(scope="module")
def cb_params():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def cfg_and_params():
    cfg = _cfg()
    from repro.models import init_params
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def _engine(cfg, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_len", 32)
    kw.setdefault("max_new_tokens", 6)
    kw.setdefault("seed", 7)
    return ContinuousBatchingEngine(cfg, **kw)


# ---------------------------------------------------------------------- #
# scheduler core (pure Python)                                            #
# ---------------------------------------------------------------------- #

def _seq(eng, toks, **kw):
    return eng.make_sequence(toks, **kw)


def test_admission_fairness_fifo():
    """Waiting queue outnumbers slots: admissions happen in strict
    arrival order, and a deferred sequence is never overtaken."""
    sched = SlotScheduler(2)
    eng_seqs = []
    for i in range(6):
        s = type("S", (), {})()
        s.uid = i
        sched.admit(s)
        eng_seqs.append(s)
    first = sched.take_admissions()
    assert [q.uid for _, q in first] == [0, 1]
    assert sched.take_admissions() == []          # no free slots
    # defer puts the sequence back at the FRONT
    slot0, q0 = first[0]
    sched.defer(slot0, q0)
    nxt = sched.take_admissions()
    assert [q.uid for _, q in nxt] == [0]         # not overtaken by 2..5
    # releases admit strictly in arrival order
    sched.release(nxt[0][0])
    sched.release(first[1][0])
    again = sched.take_admissions()
    assert [q.uid for _, q in again] == [2, 3]
    assert sched.num_waiting == 2
    # admitted_at stamps are monotone in arrival order
    assert eng_seqs[0].admitted_at < eng_seqs[1].admitted_at


def test_slot_reuse_after_release():
    sched = SlotScheduler(1)
    a, b = (type("S", (), {"uid": i})() for i in (0, 1))
    sched.admit(a)
    sched.admit(b)
    (s0, q), = sched.take_admissions()
    assert q is a
    sched.release(s0)
    (s1, q2), = sched.take_admissions()
    assert s1 == s0 and q2 is b                   # freed slot reused
    assert sched.occupancy == 1.0
    sched.release(s1)
    assert sched.idle


# ---------------------------------------------------------------------- #
# paged KV pool                                                           #
# ---------------------------------------------------------------------- #

def test_kv_page_alloc_free_no_leak():
    cfg = _cfg()
    pool = PagedKVPool(cfg, num_pages=9, page_size=4, pages_per_seq=4)
    total = pool.free_pages
    assert total == 8                              # page 0 reserved
    pool.ensure(0, 5)                              # 2 pages
    pool.ensure(1, 13)                             # 4 pages
    assert pool.pages_in_use == 6 and pool.free_pages == 2
    # growth is incremental, not re-allocation
    pool.ensure(0, 8)
    assert len(pool.page_row(0).nonzero()[0]) == 2
    pool.ensure(0, 9)
    assert pool.pages_in_use == 7
    # exhaustion allocates nothing (no partial leak)
    with pytest.raises(KVPoolExhausted):
        pool.ensure(2, 12)
    assert not pool.owns(2) and pool.free_pages == 1
    pool.release(0)
    pool.release(1)
    assert pool.pages_in_use == 0 and pool.free_pages == total
    # many admission/release cycles never leak
    for it in range(20):
        uid = 100 + it
        pool.ensure(uid, 16)
        pool.release(uid)
    assert pool.free_pages == total


def test_kv_pool_over_budget_rejected():
    pool = PagedKVPool(_cfg(), num_pages=9, page_size=4, pages_per_seq=2)
    with pytest.raises(ValueError, match="pages_per_seq"):
        pool.ensure(0, 9)                          # needs 3 > budget 2


# ---------------------------------------------------------------------- #
# engine: slot reuse / emission / boundaries                              #
# ---------------------------------------------------------------------- #

def test_engine_slot_reuse_and_no_page_leak(cfg_and_params):
    """More sequences than slots: finished sequences free slots for the
    waiting queue, every page returns to the pool, rows stream out
    per-sample via emit."""
    cfg, params = cfg_and_params
    reg = MetricsRegistry()
    eng = _engine(cfg, metrics=reg)
    seqs = [eng.make_sequence([3 + i, 4, 5]) for i in range(5)]
    emitted = []
    fin, paused = eng.generate(params, seqs, emit=lambda q: emitted.append(q.uid))
    assert len(fin) == 5 and not paused
    assert sorted(emitted) == [q.uid for q in sorted(fin, key=lambda q: q.uid)]
    assert eng.pool.pages_in_use == 0 and eng.scheduler.idle
    snap = reg.snapshot()
    adm = snap["rollout_admissions_total"]["values"]
    assert sum(v["value"] for v in adm) == 5
    assert snap["rollout_prefill_seconds"]["values"][0]["count"] >= 1
    assert snap["rollout_decode_step_seconds"]["values"][0]["count"] >= 1
    assert "rollout_slot_occupancy" in snap
    assert "rollout_kv_pages_in_use" in snap


def _greedy_tokens(cfg, params, prompt, n, page_size=4, chunk=0):
    """One rollout with an unreachable EOS; returns generated tokens."""
    eng = _engine(cfg, page_size=page_size, max_new_tokens=n,
                  eos_id=-1, temperature=1.0)
    seq = eng.make_sequence(prompt, chunk=chunk)
    items = [seq]
    while items:
        fin, paused = eng.generate(params, items)
        items = [eng.resume(q, chunk=chunk) for q in paused]
    return seq.tokens


def test_eos_exactly_at_page_boundary(cfg_and_params):
    """EOS lands on the last position of a KV page: the sequence retires
    with exact page accounting (no page held for a phantom next token)."""
    cfg, params = cfg_and_params
    prompt = [5, 6, 7]
    toks = _greedy_tokens(cfg, params, prompt, 9, page_size=4)
    # position len(toks)-1... choose the token that lands at an exact
    # page boundary (length % page_size == 0 after appending it)
    boundary_idx = None
    for i in range(len(prompt) + 1, len(toks)):    # past the prefill token
        if (i + 1) % 4 == 0:
            boundary_idx = i
            break
    assert boundary_idx is not None
    eos_tok = toks[boundary_idx]
    eng = _engine(cfg, page_size=4, max_new_tokens=9, eos_id=eos_tok)
    seq = eng.make_sequence(prompt)
    fin, _ = eng.generate(params, [seq])
    assert fin[0].tokens == toks[:boundary_idx + 1]
    assert fin[0].eos and len(fin[0].tokens) % 4 == 0
    assert eng.pool.pages_in_use == 0 and eng.pool.free_pages == \
        eng.pool.num_pages - 1


def test_eos_exactly_at_chunk_boundary(cfg_and_params):
    """EOS on the last token of a partial-rollout chunk: the sequence
    finishes in that chunk (no empty continuation), pages all free."""
    cfg, params = cfg_and_params
    prompt = [8, 9, 10]
    chunk = 3
    toks = _greedy_tokens(cfg, params, prompt, 9, chunk=chunk)
    eos_tok = toks[len(prompt) + chunk - 1]        # last token of chunk 1
    eng = _engine(cfg, max_new_tokens=9, eos_id=eos_tok)
    seq = eng.make_sequence(prompt, chunk=chunk)
    fin, paused = eng.generate(params, [seq])
    assert [q.uid for q in fin] == [seq.uid] and not paused
    assert fin[0].gen_len == chunk and fin[0].eos
    assert fin[0].tokens == toks[:len(prompt) + chunk]
    assert eng.pool.pages_in_use == 0 and not eng._parked


def test_parked_continuation_keeps_pages(cfg_and_params):
    """A paused chunk keeps its KV pages parked (no re-prefill on
    resume); trajectories match a one-shot rollout exactly."""
    cfg, params = cfg_and_params
    prompt = [11, 12, 13, 14]
    full = _greedy_tokens(cfg, params, prompt, 8)
    eng = _engine(cfg, max_new_tokens=8, eos_id=-1)
    seq = eng.make_sequence(prompt, chunk=4)
    fin, paused = eng.generate(params, [seq])
    assert paused == [seq] and not fin
    assert eng.pool.owns(seq.uid)                  # pages parked
    assert eng.pool.pages_in_use > 0
    fin, paused = eng.generate(params, [eng.resume(seq, chunk=4)])
    assert fin == [seq] and not paused
    assert seq.tokens == full
    assert eng.pool.pages_in_use == 0


def test_preempted_parked_pages_refill_deterministically(cfg_and_params):
    """Under KV-pool pressure parked pages are evicted; the continuation
    re-prefills on resume and still reproduces the same trajectory."""
    cfg, params = cfg_and_params
    prompts = [[5, 6, 7], [8, 9, 10, 11], [3, 4], [250, 251, 252]]

    def run(num_pages):
        eng = _engine(cfg, num_pages=num_pages, max_new_tokens=8, seed=3)
        items = [eng.make_sequence(p, chunk=3) for p in prompts]
        done = []
        v = 0
        while items:
            fin, paused = eng.generate(params, items, version=v)
            done += fin
            items = [eng.resume(q, chunk=3) for q in paused]
            v += 1
        return eng, {q.uid: q.tokens for q in done}

    eng_big, roomy = run(None)                     # default: headroom
    eng_small, tight = run(17)                     # forces preemption
    assert roomy == tight
    assert eng_small.pool.pages_in_use == 0


# ---------------------------------------------------------------------- #
# GRPO through the stage graph: continuous backend, fused == staged       #
# ---------------------------------------------------------------------- #

def test_grpo_cb_staged_matches_fused_fixed_seed():
    """Continuous-batching backend end-to-end: the fused facade and the
    staged dataflow train identically on a fixed seed (counter-keyed
    sampling makes trajectories batch-composition independent)."""
    from repro.api import Trainer, TrainerConfig
    from repro.core.workflow import AsyncRLRunner, WorkflowConfig
    from repro.data import PromptDataset
    from repro.engines import JaxRolloutEngine, JaxTrainEngine
    from repro.models import init_params
    from repro.rl.grpo import GRPOConfig
    from repro.training.optimizer import OptimizerConfig

    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    common = dict(mode="baseline", num_steps=2, prompts_per_step=2,
                  group_size=2, train_micro_batch=4)
    opt = OptimizerConfig(lr=3e-4, warmup_steps=2, total_steps=2,
                          schedule=cfg.lr_schedule
                          if cfg.lr_schedule != "cosine" else "constant")
    fused = AsyncRLRunner(
        WorkflowConfig(num_rollout_workers=1, rollout_batch=2,
                       num_storage_units=1, **common),
        rollout_engine=JaxRolloutEngine(cfg, group_size=2,
                                        max_new_tokens=4,
                                        backend="continuous", cb_slots=2,
                                        cb_seed=0),
        train_engine=JaxTrainEngine(cfg, params, rl=GRPOConfig(), opt=opt,
                                    global_batch=4, seq_len=24),
        prompt_stream=lambda s: PromptDataset(seed=0).prompts_for_step(
            s, 2))
    r_fused = fused.run()

    tcfg = TrainerConfig(num_steps=2, prompts_per_step=2, group_size=2,
                         rollout_workers=1, rollout_batch=2,
                         train_micro_batch=4, max_new_tokens=4, seq_len=24,
                         mode="baseline", num_storage_units=1, seed=0,
                         rollout_backend="continuous", cb_slots=2)
    r_staged = Trainer(tcfg, model_cfg=cfg, params=params).fit()

    assert len(r_fused.metrics) == len(r_staged.metrics) == 2
    for mf, ms in zip(r_fused.metrics, r_staged.metrics):
        assert mf["step"] == ms["step"]
        for k in ("loss", "policy_loss", "grad_norm", "mean_reward"):
            np.testing.assert_allclose(mf[k], ms[k], rtol=1e-4, atol=1e-5,
                                       err_msg=k)


def test_cb_crash_recovery_bit_identical_fixed_seed():
    """Kill a generate worker mid-run via deterministic fault injection:
    the leased prompts requeue at the front, the respawned replica
    re-fetches them in original FIFO order, and — because CB sampling is
    counter-keyed and parked KV pages re-prefill deterministically — the
    recovered run's data-plane rows and training metrics are bit-identical
    to an uninterrupted fixed-seed run."""
    from repro.api import Trainer, TrainerConfig
    from repro.core.obs import scoped
    from repro.core.supervision import FaultConfig
    from repro.models import init_params

    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    tcfg = TrainerConfig(num_steps=2, prompts_per_step=2, group_size=2,
                         rollout_workers=1, rollout_batch=2,
                         train_micro_batch=4, max_new_tokens=6, seq_len=24,
                         mode="baseline", num_storage_units=1, seed=0,
                         rollout_backend="continuous", cb_slots=2,
                         chunk_tokens=2, heartbeat_timeout_s=30.0,
                         max_replica_restarts=16)

    def run(faults):
        rows_seen = []
        with scoped() as reg:
            tr = Trainer(dataclasses.replace(tcfg, faults=faults),
                         model_cfg=cfg, params=params)
            orig = tr.rollout_engine.compute_rewards

            def spy(batch, **kw):
                rows_seen.extend(tuple(np.asarray(r).tolist())
                                 for r in batch["response_ids"])
                return orig(batch, **kw)

            tr.rollout_engine.compute_rewards = spy
            r = tr.fit()
            snap = reg.snapshot()
        restarts = sum(v["value"] for v in snap.get(
            "replica_restarts_total", {}).get("values", []))
        return r, rows_seen, restarts

    # seed 8 draws a crash on the first generate call even at 5%
    faults = FaultConfig(crash_p=0.05, seed=8, stages=("generate",))
    r_clean, rows_clean, restarts_clean = run(None)
    r_chaos, rows_chaos, restarts_chaos = run(faults)

    assert restarts_clean == 0 and restarts_chaos >= 1
    # exactly-once: same number of rows, and bit-for-bit the same tokens
    assert sorted(rows_chaos) == sorted(rows_clean)
    assert r_chaos.samples_trained == r_clean.samples_trained == 8
    assert len(r_chaos.metrics) == len(r_clean.metrics) == 2
    for mc, mf in zip(r_clean.metrics, r_chaos.metrics):
        for k in ("loss", "policy_loss", "grad_norm", "mean_reward"):
            np.testing.assert_array_equal(np.asarray(mc[k]),
                                          np.asarray(mf[k]), err_msg=k)


def test_cb_chunked_rollout_matches_oneshot_rows():
    """The chunked CB path (paged-KV continuations, no re-prefill)
    produces the same experience rows as one-shot CB generation."""
    from repro.engines import JaxRolloutEngine
    from repro.models import init_params

    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = [dict(tokens=np.asarray([5, 6, 7]), answer=1),
               dict(tokens=np.asarray([8, 9, 10, 11]), answer=2)]
    rng = np.random.default_rng(0)

    base = JaxRolloutEngine(cfg, group_size=2, max_new_tokens=6,
                            backend="continuous", cb_slots=2, cb_seed=3)
    rows = base.generate(params, prompts, rng)

    chunked = JaxRolloutEngine(cfg, group_size=2, max_new_tokens=6,
                               chunk_tokens=2, backend="continuous",
                               cb_slots=2, cb_seed=3)
    items, got, v = list(prompts), [], 0
    while items:
        rws, conts = chunked.generate_chunked(params, items, rng,
                                              version=v)
        got += rws
        items = conts
        v += 1
    assert sorted(r["response"].tolist() for r in got) == \
        sorted(r["response"].tolist() for r in rows)
    for r in got:
        assert len(r["chunk_versions"]) >= 1
        np.testing.assert_allclose(
            np.asarray(r["logprob"], np.float32)[r["response_mask"] > 0],
            np.asarray([x for q in rows
                        if q["response"].tolist() == r["response"].tolist()
                        for x in np.asarray(q["logprob"], np.float32)[
                            q["response_mask"] > 0]]),
            rtol=1e-5)
