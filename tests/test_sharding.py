"""Sharding rules: every parameter/cache leaf of every architecture gets a
rank-consistent PartitionSpec whose named axes divide the dims (validated
structurally against an AbstractMesh — no devices needed)."""
import functools

import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.distributed.sharding import (batch_pspecs, cache_pspecs,
                                        state_pspecs, tree_pspecs)
from repro.launch.specs import (decode_specs, params_struct, state_struct,
                                train_specs)

# jax >= 0.4.35 takes a ((name, size), ...) shape tuple
MESH = AbstractMesh((("data", 16), ("model", 16)))
POD_MESH = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _flat_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in kp), v) for kp, v in flat]


def _check(specs, shapes):
    s_flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    v_flat = jax.tree.leaves(shapes)
    assert len(s_flat) == len(v_flat)
    for spec, leaf in zip(s_flat, v_flat):
        assert len(spec) <= leaf.ndim, (spec, leaf.shape)
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= dict(zip(MESH.axis_names, MESH.axis_sizes)).get(a, 1)
            assert dim % size == 0, (spec, leaf.shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_valid(arch):
    cfg = get_config(arch)
    params = params_struct(cfg)
    specs = tree_pspecs(params, cfg, MESH)
    _check(specs, params)


@pytest.mark.parametrize("arch", ["qwen2_5_7b", "deepseek_v2_236b",
                                  "falcon_mamba_7b", "recurrentgemma_9b"])
def test_state_specs_valid(arch):
    cfg = get_config(arch)
    st = state_struct(cfg)
    specs = state_pspecs(st, cfg, MESH)
    _check(specs.params, st.params)
    _check(specs.opt_state["m"], st.opt_state["m"])


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", ["decode_32k", "long_500k"])
def test_cache_specs_valid(arch, shape):
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.supports_long_decode:
        pytest.skip("whisper long_500k skipped by design")
    cache, token, pos, ring = decode_specs(cfg, shape)
    B = token.shape[0]
    specs = cache_pspecs(cache, cfg, MESH, batch=B)
    _check(specs, cache)
    # kv_seq_shard variant also valid
    specs2 = cache_pspecs(cache, cfg, MESH, batch=B, kv_seq_shard=True)
    _check(specs2, cache)


def test_batch_specs_shard_leading_dim():
    cfg = get_config("qwen2_5_7b")
    batch = train_specs(cfg, "train_4k")
    specs = batch_pspecs(batch, cfg, POD_MESH)
    assert specs["tokens"] == P(("pod", "data"), None)
    assert specs["advantage"] == P(("pod", "data"))


def test_tp_fsdp_pattern():
    """Attention/MLP weights must shard d_model-ish over data and the
    parallel dim over model (Megatron x FSDP)."""
    cfg = get_config("qwen2_5_7b")
    params = params_struct(cfg)
    specs = tree_pspecs(params, cfg, MESH)
    flat = dict(_flat_with_paths(specs))

    def get(path):
        for k, v in flat.items():
            if k.endswith(path):
                return v
        raise KeyError(path)

    assert get("attn/wq/w") == P(None, "data", "model")   # stacked layers
    assert get("attn/wo/w") == P(None, "model", "data")
    assert get("ffn/up/w") == P(None, "data", "model")
    assert get("ffn/down/w") == P(None, "model", "data")
    assert get("embed/table") == P("model", "data")
