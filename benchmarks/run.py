"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV on stdout (strictly CSV: errors
and tracebacks go to stderr when recording a trajectory). Mapping:
  ablation            — Table 1 (baseline / +TransferQueue / +Async)
  scaling             — Fig. 10 (32→1024 chips, AsyncFlow vs colocated)
  gantt               — Fig. 11 (bubble fractions per instance)
  stability           — Fig. 12 (async vs sync reward)
  transfer_queue      — §3.5 (concurrency micro-benchmarks)
  stage_graph         — §4.1 (fused vs. staged pipeline bubbles)
  chaos               — fault injection (0/5/15% crash rates: graceful
                        degradation with exactly-once recovery)
  rollout             — §3.3 (fixed-batch vs continuous-batching rollout)
  kernels             — kernel oracle timings + kernel-vs-oracle error
  roofline            — deliverable (g): dry-run roofline summary

Trajectory convention (``--json``)
----------------------------------
``python -m benchmarks.run --json BENCH_<tag>.json [suite ...]`` writes
the machine-readable suite output next to the CSV: every row (name,
us_per_call, derived), the git revision, a UTC timestamp and the host
config, under schema ``asyncflow-bench-trajectory/v1``. One file is
committed per milestone tag (``BENCH_pr6.json``, ...), so
``git log --oneline -- 'BENCH_*.json'`` is the repo's performance
trajectory; CI records ``BENCH_ci.json`` as a build artifact on every
push. Suites that fail are recorded with their traceback under
``suites.<name>.error`` and the process exits nonzero — after the JSON
and all valid CSV rows are flushed.
"""
from __future__ import annotations

import argparse
import json
import platform
import subprocess
import sys
import time
import traceback


def _git_rev() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "HEAD"],
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:                                # noqa: BLE001
        return "unknown"


def _host_config() -> dict:
    cfg = {"python": platform.python_version(),
           "platform": platform.platform()}
    try:
        import jax
        cfg["jax"] = jax.__version__
        cfg["jax_backend"] = jax.default_backend()
    except Exception:                                # noqa: BLE001
        pass
    return cfg


def main(argv=None) -> None:
    from benchmarks import (ablation, gantt, kernel_bench, rollout_bench,
                            roofline, scaling, stability, stage_graph_bench,
                            transfer_queue_bench)

    suites = [
        ("ablation", ablation.run),
        ("scaling", scaling.run),
        ("gantt", gantt.run),
        ("stability", stability.run),
        ("transfer_queue", transfer_queue_bench.run),
        ("stage_graph", stage_graph_bench.run),
        ("chaos", stage_graph_bench.run_chaos),
        ("rollout", rollout_bench.run),
        ("kernels", kernel_bench.run),
        ("roofline", roofline.run),
    ]
    ap = argparse.ArgumentParser(
        description="AsyncFlow benchmark harness (CSV on stdout)")
    ap.add_argument("--json", dest="json_path", default="", metavar="PATH",
                    help="also record a BENCH_<tag>.json trajectory file")
    ap.add_argument("names", nargs="*",
                    help=f"suites to run (default: all) — "
                         f"{', '.join(n for n, _ in suites)}")
    args = ap.parse_args(argv)
    only = set(args.names)
    unknown = only - {n for n, _ in suites}
    if unknown:
        ap.error(f"unknown suite(s): {sorted(unknown)}")

    t_start = time.time()
    record: dict = {}
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites:
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            rows = [dict(name=r["name"], us_per_call=float(r["us_per_call"]),
                         derived=r["derived"]) for r in fn()]
        except Exception:
            failed += 1
            record[name] = {"rows": [], "error": traceback.format_exc(),
                            "elapsed_s": round(time.perf_counter() - t0, 3)}
            # stdout stays strictly CSV under --json: the ERROR row moves
            # to stderr with the traceback; flush first so streams never
            # interleave mid-row
            sys.stdout.flush()
            err_stream = sys.stderr if args.json_path else sys.stdout
            print(f"{name},ERROR,0", file=err_stream)
            err_stream.flush()
            traceback.print_exc(file=sys.stderr)
            sys.stderr.flush()
            continue
        for row in rows:
            print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
        record[name] = {"rows": rows, "error": None,
                        "elapsed_s": round(time.perf_counter() - t0, 3)}

    if args.json_path:
        doc = {
            "schema": "asyncflow-bench-trajectory/v1",
            "git_rev": _git_rev(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                       time.gmtime(t_start)),
            "elapsed_s": round(time.time() - t_start, 3),
            "config": _host_config(),
            "suites": record,
        }
        with open(args.json_path, "w") as fh:
            json.dump(doc, fh, indent=2, default=str)
            fh.write("\n")
    # exit nonzero only after every valid row and the JSON are flushed
    sys.stdout.flush()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
