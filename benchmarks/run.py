"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Mapping:
  ablation            — Table 1 (baseline / +TransferQueue / +Async)
  scaling             — Fig. 10 (32→1024 chips, AsyncFlow vs colocated)
  gantt               — Fig. 11 (bubble fractions per instance)
  stability           — Fig. 12 (async vs sync reward)
  transfer_queue      — §3.5 (concurrency micro-benchmarks)
  stage_graph         — §4.1 (fused vs. staged pipeline bubbles)
  kernels             — kernel oracle timings + kernel-vs-oracle error
  roofline            — deliverable (g): dry-run roofline summary
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (ablation, gantt, kernel_bench, roofline, scaling,
                            stability, stage_graph_bench,
                            transfer_queue_bench)

    suites = [
        ("ablation", ablation.run),
        ("scaling", scaling.run),
        ("gantt", gantt.run),
        ("stability", stability.run),
        ("transfer_queue", transfer_queue_bench.run),
        ("stage_graph", stage_graph_bench.run),
        ("kernels", kernel_bench.run),
        ("roofline", roofline.run),
    ]
    only = set(sys.argv[1:])
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites:
        if only and name not in only:
            continue
        try:
            for row in fn():
                print(f"{row['name']},{row['us_per_call']:.1f},"
                      f"{row['derived']}")
        except Exception:
            failed += 1
            print(f"{name},ERROR,0", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
