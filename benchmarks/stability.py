"""Fig. 12 reproduction: async vs sync GRPO reward trajectories on the
verifiable math task must stay close (negligible degradation)."""
from __future__ import annotations

import numpy as np


def run(steps: int = 14, seed: int = 0) -> list[dict]:
    from repro.api import Trainer, TrainerConfig

    curves = {"streaming": [], "async": []}
    for mode in curves:                   # sync on-policy vs 1-step async
        for sd in (seed, seed + 1):
            tcfg = TrainerConfig(arch="qwen2_5_7b", mode=mode,
                                 num_steps=steps, prompts_per_step=4,
                                 group_size=4, rollout_workers=2,
                                 rollout_batch=2, train_micro_batch=4,
                                 max_new_tokens=4, seq_len=20, lr=2e-3,
                                 seed=sd, reward="shaped")
            r = Trainer(tcfg).fit()
            curves[mode].append(
                [m.get("mean_reward", np.nan) for m in r.metrics])

    sync_r = np.nanmean([np.nanmean(c[-4:]) for c in curves["streaming"]])
    async_r = np.nanmean([np.nanmean(c[-4:]) for c in curves["async"]])
    sync_0 = np.nanmean([np.nanmean(c[:4]) for c in curves["streaming"]])
    gap = abs(sync_r - async_r)
    return [
        dict(name="stability_sync_final_reward", us_per_call=0.0,
             derived=round(float(sync_r), 4)),
        dict(name="stability_async_final_reward", us_per_call=0.0,
             derived=round(float(async_r), 4)),
        dict(name="stability_reward_gap", us_per_call=0.0,
             derived=round(float(gap), 4)),
        dict(name="stability_sync_improvement", us_per_call=0.0,
             derived=round(float(sync_r - sync_0), 4)),
    ]


if __name__ == "__main__":
    for row in run():
        print(row)
