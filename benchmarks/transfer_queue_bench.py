"""§3.5 micro-benchmarks: TransferQueue op latency and concurrent
read/write throughput scaling with storage units."""
from __future__ import annotations

import threading
import time

import numpy as np


def run() -> list[dict]:
    from repro.core.transfer_queue import TransferQueue

    rows = []

    # put/get latency (single-threaded)
    tq = TransferQueue(capacity=4096, tasks={"t": ["x"]},
                       num_storage_units=4)
    idxs = tq.next_indices(4096)
    payload = np.zeros(1024, np.float32)
    t0 = time.perf_counter()
    for i in idxs:
        tq.put(i, "x", payload)
    t_put = (time.perf_counter() - t0) / len(idxs)
    t0 = time.perf_counter()
    while tq.get("t", 64, timeout=0.1) is not None:
        pass
    t_get = (time.perf_counter() - t0) / (len(idxs) // 64)
    rows.append(dict(name="tq_put_row", us_per_call=t_put * 1e6,
                     derived=round(1 / t_put, 0)))
    rows.append(dict(name="tq_get_batch64", us_per_call=t_get * 1e6,
                     derived=round(1 / t_get, 0)))

    # concurrent producer/consumer throughput vs storage-unit count
    for units in (1, 2, 4, 8):
        tq = TransferQueue(capacity=8192, tasks={"t": ["x"]},
                           num_storage_units=units)
        idxs = tq.next_indices(8192)
        done = []

        def produce(shard):
            mine = idxs[shard::4]
            for i in mine:
                tq.put(i, "x", payload)

        def consume():
            n = 0
            while True:
                b = tq.get("t", 128, timeout=2.0, allow_partial=True)
                if b is None:
                    return
                n += len(b["indices"])
                if n >= len(idxs) // 2:
                    done.append(n)
                    return

        t0 = time.perf_counter()
        threads = [threading.Thread(target=produce, args=(s,))
                   for s in range(4)] + \
                  [threading.Thread(target=consume) for _ in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
        dt = time.perf_counter() - t0
        rows.append(dict(name=f"tq_concurrent_{units}units",
                         us_per_call=dt / len(idxs) * 1e6,
                         derived=round(len(idxs) / dt, 0)))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
