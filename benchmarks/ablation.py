"""Table 1 reproduction: baseline / +TransferQueue / +Async — real
wall-clock on CPU with a tiny Qwen-like model through the full stack."""
from __future__ import annotations

import time


def run(num_steps: int = 6, seed: int = 0) -> list[dict]:
    from repro.api import Trainer, TrainerConfig

    def cfg(mode, steps):
        # channel bandwidth scaled so the weight transfer costs a realistic
        # fraction of a step (at cluster scale, 7B bf16 over host network
        # takes ~100-300 ms) — the async mode's delayed update overlaps it
        return TrainerConfig(arch="qwen2_5_7b", mode=mode, num_steps=steps,
                             prompts_per_step=4, group_size=2,
                             rollout_workers=2, rollout_batch=2,
                             train_micro_batch=2, max_new_tokens=6,
                             seq_len=24, seed=seed,
                             channel_bandwidth_gbps=0.25)

    # warm the XLA compile cache so no timed mode is charged for
    # compilation (baseline consumes whole batches -> distinct jit shape)
    Trainer(cfg("streaming", 1)).fit()
    Trainer(cfg("baseline", 1)).fit()

    rows = []
    base_tput = None
    for mode, label in (("baseline", "Baseline"),
                        ("streaming", "w/TransferQueue"),
                        ("async", "(2) + w/Asyn.Opt")):
        t0 = time.time()
        r = Trainer(cfg(mode, num_steps)).fit()
        wall = time.time() - t0
        tput = r.samples_trained / wall
        if base_tput is None:
            base_tput = tput
        rows.append(dict(name=f"ablation_{mode}", us_per_call=wall * 1e6,
                         derived=round(tput / base_tput, 3), label=label,
                         throughput=round(tput, 2),
                         max_staleness=max(r.staleness_seen)))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
