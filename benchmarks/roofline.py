"""Roofline table (deliverable g): aggregates results/dryrun/*.json into
the per-(arch x shape x mesh) three-term roofline + bottleneck report,
plus arithmetic-intensity points for the hand-written kernels (flash
attention, grpo_logprob, and the fused RL hot path whose single streamed
logits pass replaces the unfused composition's three)."""
from __future__ import annotations

import glob
import json
import os


def kernel_ai_rows(N: int = 2048, V: int = 32768, S: int = 2048,
                   hd: int = 64) -> list[dict]:
    """Arithmetic intensity (flops per HBM byte, fp32) of the kernel
    layer. ``derived`` is the AI; all are far below the ~240 flops/byte
    TPU ridge, so every vocab/seq-streaming kernel is bandwidth-bound and
    logits traffic is the thing to optimize.

    The fused RL loss streams the (N, V) logits ONCE in forward (online
    LSE + entropy + target pickup in the same pass) and once in backward
    (softmax recomputed from saved per-token statistics); the unfused
    token_logprobs + kl_penalty + clipped_policy_loss composition costs
    three forward-side reads (log-softmax output, entropy pass, autodiff
    residual) for the same ~6 flops per element.
    """
    flops_per_elt = 6.0                      # max-scan, sub, exp, 2 acc, cmp
    bytes_elt = 4.0
    ai_fused = flops_per_elt / bytes_elt
    ai_unfused = flops_per_elt / (3 * bytes_elt)
    ai_logprob = 5.0 / bytes_elt             # no surrogate/KL epilogue
    # flash attention: 2 matmuls (4*S^2*hd flops) over ~4 S x hd tensors
    ai_flash = (4.0 * S * S * hd) / (4 * bytes_elt * S * hd)
    return [
        dict(name=f"kernel_ai_flash_attention_{S}x{hd}", us_per_call=0.0,
             derived=round(ai_flash, 3)),
        dict(name=f"kernel_ai_grpo_logprob_{N}x{V}", us_per_call=0.0,
             derived=round(ai_logprob, 3)),
        dict(name=f"kernel_ai_rl_loss_unfused_{N}x{V}", us_per_call=0.0,
             derived=round(ai_unfused, 3)),
        dict(name=f"kernel_ai_rl_loss_fused_{N}x{V}", us_per_call=0.0,
             derived=round(ai_fused, 3)),
        # the headline: forward logits HBM traffic, unfused over fused
        dict(name="kernel_logits_reads_unfused_over_fused", us_per_call=0.0,
             derived=3.0),
    ]

HEADER = ("arch", "shape", "mesh", "t_compute", "t_memory", "t_collective",
          "bottleneck", "useful_ratio")


def load(dirname: str = "results/dryrun") -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        try:
            recs.append(json.load(open(f)))
        except Exception:
            pass
    return recs


def table(recs: list[dict]) -> str:
    lines = ["| " + " | ".join(HEADER) + " |",
             "|" + "---|" * len(HEADER)]
    for r in sorted(recs, key=lambda r: (r.get("mesh", ""), r["arch"],
                                         r["shape"])):
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | skipped | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERR | | | {r.get('error', '?')[:40]} | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute']:.3e} | {r['t_memory']:.3e} "
            f"| {r['t_collective']:.3e} | **{r['bottleneck']}** "
            f"| {min(r['useful_flops_ratio'], 1.0):.2f} |")
    return "\n".join(lines)


def run() -> list[dict]:
    recs = load()
    rows = []
    ok = [r for r in recs if r.get("status") == "ok"]
    for r in ok:
        if r["mesh"] != "single":
            continue
        dom_t = max(r["t_compute"], r["t_memory"], r["t_collective"])
        rows.append(dict(
            name=f"roofline_{r['arch']}_{r['shape']}",
            us_per_call=dom_t * 1e6,
            derived=r["bottleneck"]))
    n_ok = len(ok)
    n_skip = sum(1 for r in recs if r.get("status") == "skipped")
    n_err = len(recs) - n_ok - n_skip
    rows.append(dict(name="dryrun_combos_ok", us_per_call=0.0, derived=n_ok))
    rows.append(dict(name="dryrun_combos_skipped", us_per_call=0.0,
                     derived=n_skip))
    rows.append(dict(name="dryrun_combos_failed", us_per_call=0.0,
                     derived=n_err))
    rows.extend(kernel_ai_rows())
    return rows


if __name__ == "__main__":
    print(table(load()))
    for r in kernel_ai_rows():
        print(f"{r['name']}: AI={r['derived']}")
