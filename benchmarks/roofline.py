"""Roofline table (deliverable g): aggregates results/dryrun/*.json into
the per-(arch x shape x mesh) three-term roofline + bottleneck report."""
from __future__ import annotations

import glob
import json
import os

HEADER = ("arch", "shape", "mesh", "t_compute", "t_memory", "t_collective",
          "bottleneck", "useful_ratio")


def load(dirname: str = "results/dryrun") -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        try:
            recs.append(json.load(open(f)))
        except Exception:
            pass
    return recs


def table(recs: list[dict]) -> str:
    lines = ["| " + " | ".join(HEADER) + " |",
             "|" + "---|" * len(HEADER)]
    for r in sorted(recs, key=lambda r: (r.get("mesh", ""), r["arch"],
                                         r["shape"])):
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | skipped | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERR | | | {r.get('error', '?')[:40]} | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute']:.3e} | {r['t_memory']:.3e} "
            f"| {r['t_collective']:.3e} | **{r['bottleneck']}** "
            f"| {min(r['useful_flops_ratio'], 1.0):.2f} |")
    return "\n".join(lines)


def run() -> list[dict]:
    recs = load()
    rows = []
    ok = [r for r in recs if r.get("status") == "ok"]
    for r in ok:
        if r["mesh"] != "single":
            continue
        dom_t = max(r["t_compute"], r["t_memory"], r["t_collective"])
        rows.append(dict(
            name=f"roofline_{r['arch']}_{r['shape']}",
            us_per_call=dom_t * 1e6,
            derived=r["bottleneck"]))
    n_ok = len(ok)
    n_skip = sum(1 for r in recs if r.get("status") == "skipped")
    n_err = len(recs) - n_ok - n_skip
    rows.append(dict(name="dryrun_combos_ok", us_per_call=0.0, derived=n_ok))
    rows.append(dict(name="dryrun_combos_skipped", us_per_call=0.0,
                     derived=n_skip))
    rows.append(dict(name="dryrun_combos_failed", us_per_call=0.0,
                     derived=n_err))
    return rows


if __name__ == "__main__":
    print(table(load()))
