"""Fixed-batch vs continuous-batching rollout on a length-skewed mix.

The workload that motivates the `engines/continuous_batching` subsystem
(Laminar / ROLL-Flash's long-tail argument): most requests want a few
tokens, a minority want many. The fixed-batch engine decodes every batch
in lockstep to the longest budget — short requests pay for the tail.
The continuous batcher retires a finished sequence immediately, admits
the next waiting prompt into the freed slot, and prefills prompts in one
forward instead of scanning them token by token.

Reported rows: wall-clock tokens/s per engine (useful tokens only —
capped at each request's budget and truncated at EOS for both engines),
the CB/fixed speedup, and the CB scheduler's slot occupancy / admission
counters from the metrics registry.
"""
from __future__ import annotations

import time


def _budgets(n: int, short_new: int, long_new: int) -> list:
    """75% short / 25% long-tail per-request token budgets."""
    return [long_new if i % 4 == 0 else short_new for i in range(n)]


def _workload(smoke: bool) -> dict:
    if smoke:
        return dict(requests=8, batch=4, short_new=2, long_new=16)
    return dict(requests=16, batch=4, short_new=4, long_new=48)


def run(render: bool = False, smoke: bool = False) -> list:
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.obs import MetricsRegistry
    from repro.data import PromptDataset
    from repro.data.tokenizer import ByteTokenizer
    from repro.engines.continuous_batching import ContinuousBatchingEngine
    from repro.models import init_params
    from repro.rl.sampling import generate as fixed_generate

    w = _workload(smoke)
    # big enough per-step compute that scheduling (not dispatch overhead)
    # decides throughput — the regime the subsystem targets
    cfg = dataclasses.replace(
        get_config("qwen2_5_7b").reduced(), num_layers=4, d_model=256,
        d_ff=1024, num_heads=4, num_kv_heads=4, head_dim=64,
        vocab_size=ByteTokenizer.vocab_size)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = PromptDataset(seed=0).prompts_for_step(0, w["requests"])
    budgets = _budgets(w["requests"], w["short_new"], w["long_new"])
    eos = ByteTokenizer.eos_id

    # ---- fixed-batch arm: lockstep decode to the longest budget ----
    def fixed_pass():
        toks = 0
        for i in range(0, len(prompts), w["batch"]):
            chunk = prompts[i:i + w["batch"]]
            bud = budgets[i:i + w["batch"]]
            rows = fixed_generate(params, cfg,
                                  [p["tokens"] for p in chunk], i,
                                  max_new_tokens=w["long_new"],
                                  temperature=1.0)
            toks += sum(min(len(r["response_ids"]), b)
                        for r, b in zip(rows, bud))
        return toks

    # ---- continuous arm: slot scheduler + paged KV, per-request budget ----
    max_len = max(len(p["tokens"]) for p in prompts) + w["long_new"]

    def cb_pass(metrics):
        eng = ContinuousBatchingEngine(
            cfg, num_slots=w["batch"], page_size=8, max_len=max_len,
            max_new_tokens=w["long_new"], temperature=1.0, seed=0,
            metrics=metrics)
        seqs = [eng.make_sequence(p["tokens"], max_new=b)
                for p, b in zip(prompts, budgets)]
        done, _ = eng.generate(params, seqs)
        return sum(q.gen_len for q in done), eng

    fixed_pass()                            # warm both XLA caches
    cb_pass(MetricsRegistry())
    t0 = time.perf_counter()
    fixed_tokens = fixed_pass()
    fixed_wall = time.perf_counter() - t0

    reg = MetricsRegistry()
    t0 = time.perf_counter()
    cb_tokens, eng = cb_pass(reg)
    cb_wall = time.perf_counter() - t0

    fixed_tps = fixed_tokens / fixed_wall
    cb_tps = cb_tokens / cb_wall
    snap = reg.snapshot()
    admissions = sum(v["value"] for v in
                     snap["rollout_admissions_total"]["values"])
    prefill_s = sum(v["sum"] for v in
                    snap["rollout_prefill_seconds"]["values"])
    decode_s = sum(v["sum"] for v in
                   snap["rollout_decode_step_seconds"]["values"])
    if render:
        print(f"fixed:      {fixed_tokens} tok in {fixed_wall:.2f}s "
              f"({fixed_tps:.1f} tok/s)")
        print(f"continuous: {cb_tokens} tok in {cb_wall:.2f}s "
              f"({cb_tps:.1f} tok/s) — {admissions:.0f} admissions, "
              f"prefill {prefill_s:.2f}s / decode {decode_s:.2f}s")
    return [
        dict(name="rollout_fixed_tokens_per_s",
             us_per_call=fixed_wall * 1e6, derived=round(fixed_tps, 1)),
        dict(name="rollout_cb_tokens_per_s",
             us_per_call=cb_wall * 1e6, derived=round(cb_tps, 1)),
        dict(name="rollout_cb_speedup",
             us_per_call=cb_wall * 1e6,
             derived=round(cb_tps / fixed_tps, 3)),
        dict(name="rollout_cb_admissions",
             us_per_call=cb_wall * 1e6, derived=int(admissions)),
        dict(name="rollout_cb_prefill_frac",
             us_per_call=prefill_s * 1e6,
             derived=round(prefill_s / max(prefill_s + decode_s, 1e-9),
                           3)),
    ]


def main(argv=None) -> int:
    """Standalone entry (CI smoke mode): CSV on stdout, optional JSON."""
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI")
    ap.add_argument("--json", dest="json_path", default="", metavar="PATH")
    args = ap.parse_args(argv)
    rows = run(render=True, smoke=args.smoke)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if args.json_path:
        with open(args.json_path, "w") as fh:
            json.dump({"schema": "asyncflow-bench-trajectory/v1",
                       "suites": {"rollout": {"rows": rows, "error": None}},
                       "smoke": args.smoke}, fh, indent=2, default=str)
            fh.write("\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
