"""Kernel-layer benchmark: jnp-oracle wall time on CPU (the Pallas kernels
are TPU-target; interpret mode is a correctness harness, not a timing
one) + allclose deltas vs the kernels."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run() -> list[dict]:
    from repro.kernels.decode_attention import (decode_attention,
                                                decode_attention_ref)
    from repro.kernels.flash_attention import (flash_attention,
                                               flash_attention_ref)
    from repro.kernels.grpo_logprob import grpo_logprob, grpo_logprob_ref
    from repro.kernels.mamba_scan import mamba_scan, mamba_scan_ref
    from repro.kernels.rglru_scan import rglru_scan, rglru_scan_ref

    key = jax.random.PRNGKey(0)
    k = lambda i: jax.random.fold_in(key, i)
    rows = []

    B, S, H, hd = 1, 512, 4, 64
    q = jax.random.normal(k(1), (B, S, H, hd))
    kv = jax.random.normal(k(2), (B, S, H, hd))
    ref = jax.jit(lambda q, a, b: flash_attention_ref(q, a, b))
    t = _time(ref, q, kv, kv)
    err = float(jnp.abs(flash_attention(q, kv, kv)
                        - flash_attention_ref(q, kv, kv)).max())
    rows.append(dict(name="flash_attention_ref_cpu", us_per_call=t * 1e6,
                     derived=err))

    S = 4096
    qd = jax.random.normal(k(3), (2, 1, H, hd))
    kc = jax.random.normal(k(4), (2, S, H, hd))
    valid = jnp.ones((2, S), bool)
    ref = jax.jit(lambda a, b, c, v: decode_attention_ref(a, b, c, v))
    t = _time(ref, qd, kc, kc, valid)
    err = float(jnp.abs(decode_attention(qd, kc, kc, valid)
                        - decode_attention_ref(qd, kc, kc, valid)).max())
    rows.append(dict(name="decode_attention_ref_cpu", us_per_call=t * 1e6,
                     derived=err))

    a = jax.random.uniform(k(5), (2, 1024, 256), minval=0.5, maxval=0.99)
    b = jax.random.normal(k(6), (2, 1024, 256))
    ref = jax.jit(rglru_scan_ref)
    t = _time(ref, a, b)
    err = float(jnp.abs(rglru_scan(a, b) - rglru_scan_ref(a, b)).max())
    rows.append(dict(name="rglru_scan_ref_cpu", us_per_call=t * 1e6,
                     derived=err))

    x = jax.random.normal(k(7), (1, 512, 256))
    dt = 0.1 * jax.nn.softplus(jax.random.normal(k(8), (1, 512, 256)))
    A = -jnp.abs(jax.random.normal(k(9), (256, 16)))
    bb = jax.random.normal(k(10), (1, 512, 16))
    cc = jax.random.normal(k(11), (1, 512, 16))
    ref = jax.jit(mamba_scan_ref)
    t = _time(ref, x, dt, A, bb, cc)
    err = float(jnp.abs(mamba_scan(x, dt, A, bb, cc)
                        - mamba_scan_ref(x, dt, A, bb, cc)).max())
    rows.append(dict(name="mamba_scan_ref_cpu", us_per_call=t * 1e6,
                     derived=err))

    lg = 5 * jax.random.normal(k(12), (1024, 8192))
    tg = jax.random.randint(k(13), (1024,), 0, 8192)
    ref = jax.jit(grpo_logprob_ref)
    t = _time(ref, lg, tg)
    lp, _ = grpo_logprob(lg, tg)
    lpr, _ = grpo_logprob_ref(lg, tg)
    rows.append(dict(name="grpo_logprob_ref_cpu", us_per_call=t * 1e6,
                     derived=float(jnp.abs(lp - lpr).max())))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
