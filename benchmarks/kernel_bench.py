"""Kernel-layer benchmark: jnp-oracle wall time on CPU (the Pallas kernels
are TPU-target; interpret mode is a correctness harness, not a timing
one) + allclose deltas vs the kernels + the fused RL hot-path:
``fused_rl_loss`` forward+backward against the unfused three-op
composition (token_logprobs + kl_penalty + clipped_policy_loss).

Standalone CLI: ``python -m benchmarks.kernel_bench [--smoke] [--json P]``
— the CI kernel smoke lane runs ``--smoke --json BENCH_ci_kernels.json``
(reduced shapes + an interpret-mode parity row for the fused kernel).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def fused_rl_loss_rows(B, S, V, *, include_interpret=False,
                       iters=2) -> list[dict]:
    """value_and_grad wall time: fused one-pass actor loss vs the unfused
    composition on the same (B, S, V) logits. ``derived`` on the fused
    row is the speedup (>1 means the fusion wins)."""
    from repro.rl.loss import (clipped_policy_loss, fused_actor_loss,
                               kl_penalty, token_logprobs)

    key = jax.random.PRNGKey(7)
    k = lambda i: jax.random.fold_in(key, i)
    logits = 3 * jax.random.normal(k(1), (B, S, V))
    tg = jax.random.randint(k(2), (B, S), 0, V)
    adv = jax.random.normal(k(5), (B,))
    mask = jnp.ones((B, S))
    # realistic ratios near 1: old/ref policies a small perturbation away
    # from the current one (otherwise exp(ref - lp) in the k3 KL explodes)
    from repro.rl.loss import token_logprobs as _tlp
    lp0 = jax.lax.stop_gradient(_tlp(logits, tg)[0])
    old = lp0 + 0.1 * jax.random.normal(k(3), (B, S))
    ref = lp0 + 0.1 * jax.random.normal(k(4), (B, S))

    def unfused(lg):
        lp, ent = token_logprobs(lg, tg)
        pl, _ = clipped_policy_loss(lp, old, adv, mask)
        kl = kl_penalty(lp, ref, mask)
        ent_mean = (ent * mask).sum() / mask.sum()
        return pl + 0.05 * kl - 0.01 * ent_mean

    def fused(lg):
        loss, _ = fused_actor_loss(lg, tg, old, adv, mask, ref_logprob=ref,
                                   kl_coef=0.05, entropy_coef=0.01)
        return loss

    g_unf = jax.jit(jax.value_and_grad(unfused))
    g_fus = jax.jit(jax.value_and_grad(fused))
    t_unf = _time(g_unf, logits, iters=iters)
    t_fus = _time(g_fus, logits, iters=iters)
    (v_u, d_u), (v_f, d_f) = g_unf(logits), g_fus(logits)
    err = max(float(jnp.abs(v_u - v_f)), float(jnp.abs(d_u - d_f).max()))
    rows = [
        dict(name=f"rl_loss_unfused_fwdbwd_{B * S}x{V}",
             us_per_call=t_unf * 1e6, derived=err),
        dict(name=f"rl_loss_fused_fwdbwd_{B * S}x{V}",
             us_per_call=t_fus * 1e6, derived=t_unf / t_fus),
    ]
    if include_interpret:
        from repro.kernels.fused_rl_loss import (fused_rl_loss,
                                                 fused_rl_loss_ref)
        n, v = 32, 512
        lg = 3 * jax.random.normal(k(6), (n, v))
        tgs = jax.random.randint(k(7), (n,), 0, v)
        lps = jax.nn.log_softmax(lg)[jnp.arange(n), tgs]
        olds = lps + 0.1 * jax.random.normal(k(8), (n,))
        refs_lp = lps + 0.1 * jax.random.normal(k(9), (n,))
        advs = jax.random.normal(k(10), (n,))
        t0 = time.perf_counter()
        outs = fused_rl_loss(lg, tgs, olds, refs_lp, advs,
                             use_pallas=True, block_n=8, block_v=128)
        refs = fused_rl_loss_ref(lg, tgs, olds, refs_lp, advs)
        # relative: kl = exp(d)-d-1 amplifies fp32 logprob noise
        perr = max(float(jnp.abs(o - r).max() / (jnp.abs(r).max() + 1.0))
                   for o, r in zip(outs, refs))
        rows.append(dict(name="fused_rl_loss_interpret_parity",
                         us_per_call=(time.perf_counter() - t0) * 1e6,
                         derived=perr))
    return rows


def run(smoke: bool = False) -> list[dict]:
    if smoke:
        # CI lane: reduced shapes + interpret-mode parity, seconds not
        # minutes — the full run exercises the paper-scale vocab instead
        rows = fused_rl_loss_rows(4, 64, 8192, include_interpret=True)
        return rows + _oracle_rows()
    return _oracle_rows() + fused_rl_loss_rows(16, 128, 32768)


def _oracle_rows() -> list[dict]:
    from repro.kernels.decode_attention import (decode_attention,
                                                decode_attention_ref)
    from repro.kernels.flash_attention import (flash_attention,
                                               flash_attention_ref)
    from repro.kernels.grpo_logprob import grpo_logprob, grpo_logprob_ref
    from repro.kernels.mamba_scan import mamba_scan, mamba_scan_ref
    from repro.kernels.rglru_scan import rglru_scan, rglru_scan_ref

    key = jax.random.PRNGKey(0)
    k = lambda i: jax.random.fold_in(key, i)
    rows = []

    B, S, H, hd = 1, 512, 4, 64
    q = jax.random.normal(k(1), (B, S, H, hd))
    kv = jax.random.normal(k(2), (B, S, H, hd))
    ref = jax.jit(lambda q, a, b: flash_attention_ref(q, a, b))
    t = _time(ref, q, kv, kv)
    err = float(jnp.abs(flash_attention(q, kv, kv)
                        - flash_attention_ref(q, kv, kv)).max())
    rows.append(dict(name="flash_attention_ref_cpu", us_per_call=t * 1e6,
                     derived=err))

    S = 4096
    qd = jax.random.normal(k(3), (2, 1, H, hd))
    kc = jax.random.normal(k(4), (2, S, H, hd))
    valid = jnp.ones((2, S), bool)
    ref = jax.jit(lambda a, b, c, v: decode_attention_ref(a, b, c, v))
    t = _time(ref, qd, kc, kc, valid)
    err = float(jnp.abs(decode_attention(qd, kc, kc, valid)
                        - decode_attention_ref(qd, kc, kc, valid)).max())
    rows.append(dict(name="decode_attention_ref_cpu", us_per_call=t * 1e6,
                     derived=err))

    a = jax.random.uniform(k(5), (2, 1024, 256), minval=0.5, maxval=0.99)
    b = jax.random.normal(k(6), (2, 1024, 256))
    ref = jax.jit(rglru_scan_ref)
    t = _time(ref, a, b)
    err = float(jnp.abs(rglru_scan(a, b) - rglru_scan_ref(a, b)).max())
    rows.append(dict(name="rglru_scan_ref_cpu", us_per_call=t * 1e6,
                     derived=err))

    x = jax.random.normal(k(7), (1, 512, 256))
    dt = 0.1 * jax.nn.softplus(jax.random.normal(k(8), (1, 512, 256)))
    A = -jnp.abs(jax.random.normal(k(9), (256, 16)))
    bb = jax.random.normal(k(10), (1, 512, 16))
    cc = jax.random.normal(k(11), (1, 512, 16))
    ref = jax.jit(mamba_scan_ref)
    t = _time(ref, x, dt, A, bb, cc)
    err = float(jnp.abs(mamba_scan(x, dt, A, bb, cc)
                        - mamba_scan_ref(x, dt, A, bb, cc)).max())
    rows.append(dict(name="mamba_scan_ref_cpu", us_per_call=t * 1e6,
                     derived=err))

    lg = 5 * jax.random.normal(k(12), (1024, 8192))
    tg = jax.random.randint(k(13), (1024,), 0, 8192)
    ref = jax.jit(grpo_logprob_ref)
    t = _time(ref, lg, tg)
    lp, _ = grpo_logprob(lg, tg)
    lpr, _ = grpo_logprob_ref(lg, tg)
    rows.append(dict(name="grpo_logprob_ref_cpu", us_per_call=t * 1e6,
                     derived=float(jnp.abs(lp - lpr).max())))
    return rows


def main(argv=None) -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description="kernel benchmarks")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shapes + interpret parity (CI lane)")
    ap.add_argument("--json", dest="json_path", default="", metavar="PATH",
                    help="write an asyncflow-bench-trajectory/v1 file")
    args = ap.parse_args(argv)
    t0 = time.time()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    if args.json_path:
        from benchmarks.run import _git_rev, _host_config
        doc = {"schema": "asyncflow-bench-trajectory/v1",
               "git_rev": _git_rev(),
               "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                          time.gmtime(t0)),
               "elapsed_s": round(time.time() - t0, 3),
               "config": _host_config(),
               "suites": {"kernels": {"rows": rows, "error": None,
                                      "elapsed_s": round(
                                          time.time() - t0, 3)}}}
        with open(args.json_path, "w") as fh:
            json.dump(doc, fh, indent=2, default=str)
            fh.write("\n")


if __name__ == "__main__":
    main()
