"""Fig. 10 reproduction: end-to-end throughput & scaling 32→1024 chips,
AsyncFlow vs colocated (verl-like), via the calibrated simulator."""
from __future__ import annotations


def run() -> list[dict]:
    from repro.configs import get_config
    from repro.core.planner import (ClusterPlan, Workload, plan_resources,
                                    simulate)

    rows = []
    for arch in ("qwen2_5_7b", "qwen2_5_32b"):
        cfg = get_config(arch)
        w = Workload(prompts_per_step=256, group_size=8,
                     mean_response_len=2048, num_steps=6)
        tput_at = {}
        for n in (32, 64, 128, 256, 512, 1024):
            plan = plan_resources(cfg, n, w, mode="separated_async").plan
            af = simulate(cfg, plan, w, "separated_async")
            verl = simulate(
                cfg, ClusterPlan(n, n, n, rollout_tp=4, train_tp=8,
                                 reshard_s=1.0 + 0.002 * n),
                w, "colocated")
            ratio = (af["throughput_samples_per_s"]
                     / verl["throughput_samples_per_s"])
            tput_at[n] = af["throughput_samples_per_s"]
            rows.append(dict(
                name=f"scaling_{arch}_{n}",
                us_per_call=1e6 / af["throughput_samples_per_s"],
                derived=round(ratio, 3),
                asyncflow_tput=round(af["throughput_samples_per_s"], 2),
                verl_tput=round(verl["throughput_samples_per_s"], 2),
                split=f"{plan.rollout_chips}/{plan.train_chips}"))
        # linearity over 16x expansion (64 -> 1024), paper reports 0.65-0.88
        lin = tput_at[1024] / (tput_at[64] * 16)
        rows.append(dict(name=f"scaling_{arch}_linearity_16x",
                         us_per_call=0.0, derived=round(lin, 3)))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
