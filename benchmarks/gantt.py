"""Fig. 11 reproduction: per-instance execution timeline + bubble
fractions of the optimized async workflow vs the baseline.

``python -m benchmarks.gantt --trace BENCH_ci_trace.json`` additionally
writes one Perfetto-loadable Chrome trace per mode
(``BENCH_ci_trace_baseline.json`` / ``..._async.json``) next to the
``BENCH_*.json`` trajectory — load them at https://ui.perfetto.dev.
"""
from __future__ import annotations

import argparse
import pathlib

import numpy as np


def run(render: bool = False, trace: str = "") -> list[dict]:
    from repro.api import Trainer, TrainerConfig

    rows = []
    for mode in ("baseline", "async"):
        tcfg = TrainerConfig(arch="qwen2_5_7b", mode=mode, num_steps=6,
                             prompts_per_step=4, group_size=2,
                             rollout_workers=2, rollout_batch=2,
                             train_micro_batch=2, max_new_tokens=6,
                             seq_len=24, channel_bandwidth_gbps=0.25)
        r = Trainer(tcfg).fit()
        bf = r.bubble_fraction
        rollout_bubbles = [v for k, v in bf.items() if k.startswith("rollout")]
        rows.append(dict(name=f"gantt_{mode}_rollout_bubble",
                         us_per_call=r.wall_time_s * 1e6,
                         derived=round(float(np.mean(rollout_bubbles)), 3)))
        rows.append(dict(name=f"gantt_{mode}_train_bubble",
                         us_per_call=r.wall_time_s * 1e6,
                         derived=round(bf.get("train-0", 0.0), 3)))
        if trace:
            p = pathlib.Path(trace)
            out = p.with_name(f"{p.stem}_{mode}{p.suffix or '.json'}")
            r.log.to_chrome_trace(path=str(out))
            if render:
                print(f"wrote chrome trace: {out}")
        if render:
            print(f"--- {mode} ---")
            print(r.log.render_gantt(100))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", nargs="?", const="gantt_trace.json",
                    default="", metavar="PATH",
                    help="write a Chrome trace per mode (PATH stem + mode)")
    args = ap.parse_args()
    for row in run(render=True, trace=args.trace):
        print(row)
