"""Fig. 11 reproduction: per-instance execution timeline + bubble
fractions of the optimized async workflow vs the baseline."""
from __future__ import annotations

import numpy as np


def run(render: bool = False) -> list[dict]:
    from repro.api import Trainer, TrainerConfig

    rows = []
    for mode in ("baseline", "async"):
        tcfg = TrainerConfig(arch="qwen2_5_7b", mode=mode, num_steps=6,
                             prompts_per_step=4, group_size=2,
                             rollout_workers=2, rollout_batch=2,
                             train_micro_batch=2, max_new_tokens=6,
                             seq_len=24, channel_bandwidth_gbps=0.25)
        r = Trainer(tcfg).fit()
        bf = r.bubble_fraction
        rollout_bubbles = [v for k, v in bf.items() if k.startswith("rollout")]
        rows.append(dict(name=f"gantt_{mode}_rollout_bubble",
                         us_per_call=r.wall_time_s * 1e6,
                         derived=round(float(np.mean(rollout_bubbles)), 3)))
        rows.append(dict(name=f"gantt_{mode}_train_bubble",
                         us_per_call=r.wall_time_s * 1e6,
                         derived=round(bf.get("train-0", 0.0), 3)))
        if render:
            print(f"--- {mode} ---")
            print(r.log.render_gantt(100))
    return rows


if __name__ == "__main__":
    for row in run(render=True):
        print(row)
