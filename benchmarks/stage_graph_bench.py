"""Fused vs. staged pipeline bubble fractions (paper §4.1 / Fig. 11).

Runs the same GRPO+KL workload twice through the async workflow:

* fused  — the legacy two-task shape: generation + reference inference +
  reward + advantage execute monolithically inside each generate() call
  (``AsyncRLRunner``), so no intermediate task streams on its own.
* staged — the stage-graph dataflow: generate → ref_inference →
  reward/advantage → actor_update, each streaming through its own
  TransferQueue controller over one shared data plane.

Reports per-role bubble fractions and wall time for both. The staged
pipeline moves reference inference and reward scoring off the rollout
workers' critical path onto their own streaming workers, which shows up
as a much shorter wall time (and correspondingly idle rollout workers —
generation alone no longer bounds the step).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _workload():
    return dict(num_steps=4, prompts_per_step=4, group_size=2,
                rollout_workers=2, rollout_batch=2, train_micro_batch=4,
                max_new_tokens=6, seq_len=24, kl_coef=0.05, mode="async")


def run(render: bool = False) -> list[dict]:
    import jax

    from repro.api import Trainer, TrainerConfig
    from repro.configs import get_config
    from repro.core.workflow import AsyncRLRunner, WorkflowConfig
    from repro.data import PromptDataset
    from repro.data.tokenizer import ByteTokenizer
    from repro.engines import JaxRolloutEngine, JaxTrainEngine
    from repro.models import init_params
    from repro.rl.grpo import GRPOConfig

    w = _workload()
    cfg = dataclasses.replace(
        get_config("qwen2_5_7b").reduced(), num_layers=2, d_model=64,
        d_ff=128, num_heads=2, num_kv_heads=2, head_dim=32,
        vocab_size=ByteTokenizer.vocab_size)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rows = []

    # ---- fused: monolithic generate() through the legacy facade ----
    ref = jax.tree.map(lambda a: a.copy(), params)
    fused = AsyncRLRunner(
        WorkflowConfig(mode=w["mode"],
                       num_rollout_workers=w["rollout_workers"],
                       rollout_batch=w["rollout_batch"],
                       train_micro_batch=w["train_micro_batch"],
                       prompts_per_step=w["prompts_per_step"],
                       group_size=w["group_size"],
                       num_steps=w["num_steps"],
                       extra_columns=("ref_logprob",)),
        rollout_engine=JaxRolloutEngine(
            cfg, group_size=w["group_size"],
            max_new_tokens=w["max_new_tokens"], ref_params=ref),
        train_engine=JaxTrainEngine(
            cfg, params, rl=GRPOConfig(kl_coef=w["kl_coef"]),
            global_batch=w["prompts_per_step"] * w["group_size"],
            seq_len=w["seq_len"]),
        prompt_stream=lambda s: PromptDataset(seed=0).prompts_for_step(
            s, w["prompts_per_step"]))
    r_fused = fused.run()

    # ---- staged: the grpo stage-graph dataflow ----
    tcfg = TrainerConfig(
        mode=w["mode"], num_steps=w["num_steps"],
        prompts_per_step=w["prompts_per_step"],
        group_size=w["group_size"],
        rollout_workers=w["rollout_workers"],
        rollout_batch=w["rollout_batch"],
        train_micro_batch=w["train_micro_batch"],
        max_new_tokens=w["max_new_tokens"], seq_len=w["seq_len"],
        kl_coef=w["kl_coef"], seed=0)
    r_staged = Trainer(tcfg, model_cfg=cfg).fit()

    # ---- planner-sized: identical dataflow, every stage left at
    # num_workers=0 and auto-sized from the analytic cost model; the
    # elastic monitor may rebalance pools mid-run ----
    pcfg = dataclasses.replace(tcfg, rollout_workers=0,
                               auto_size_workers=True,
                               elastic_interval_s=0.2)
    r_planned = Trainer(pcfg, model_cfg=cfg).fit()

    for label, r in (("fused", r_fused), ("staged", r_staged),
                     ("planned", r_planned)):
        bf = r.bubble_fraction
        roll = [v for k, v in bf.items() if k.startswith("rollout")]
        rows.append(dict(name=f"stage_graph_{label}_rollout_bubble",
                         us_per_call=r.wall_time_s * 1e6,
                         derived=round(float(np.mean(roll)), 3)))
        rows.append(dict(name=f"stage_graph_{label}_train_bubble",
                         us_per_call=r.wall_time_s * 1e6,
                         derived=round(bf.get("train-0", 0.0), 3)))
        if render:
            print(f"--- {label}: wall {r.wall_time_s:.2f}s ---")
            print(r.log.render_gantt(100))
    return rows


if __name__ == "__main__":
    for row in run(render=True):
        print(row)
