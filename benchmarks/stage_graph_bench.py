"""Fused vs. staged pipeline bubble fractions (paper §4.1 / Fig. 11).

Runs the same GRPO+KL workload twice through the async workflow:

* fused  — the legacy two-task shape: generation + reference inference +
  reward + advantage execute monolithically inside each generate() call
  (``AsyncRLRunner``), so no intermediate task streams on its own.
* staged — the stage-graph dataflow: generate → ref_inference →
  reward/advantage → actor_update, each streaming through its own
  TransferQueue controller over one shared data plane.

Reports per-role bubble fractions and wall time for both. The staged
pipeline moves reference inference and reward scoring off the rollout
workers' critical path onto their own streaming workers, which shows up
as a much shorter wall time (and correspondingly idle rollout workers —
generation alone no longer bounds the step).

``run_chaos`` is the fault-injection arm: the same staged GRPO workload
under deterministic crash injection at 0% / 5% / 15% per generate call.
Crashed replicas are fenced, their leased prompts requeue to the front of
the ready set, and the supervisor respawns replacements — the arm proves
graceful degradation with ZERO lost or duplicated experience rows at
every rate (crashes fire before the generate verb consumes compute, so
recovery costs only the respawn and throughput stays near the fault-free
baseline). Standalone:

  PYTHONPATH=src python -m benchmarks.stage_graph_bench \\
      --chaos --smoke --json BENCH_ci_faults.json
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _workload():
    return dict(num_steps=4, prompts_per_step=4, group_size=2,
                rollout_workers=2, rollout_batch=2, train_micro_batch=4,
                max_new_tokens=6, seq_len=24, kl_coef=0.05, mode="async")


def run(render: bool = False) -> list[dict]:
    import jax

    from repro.api import Trainer, TrainerConfig
    from repro.configs import get_config
    from repro.core.workflow import AsyncRLRunner, WorkflowConfig
    from repro.data import PromptDataset
    from repro.data.tokenizer import ByteTokenizer
    from repro.engines import JaxRolloutEngine, JaxTrainEngine
    from repro.models import init_params
    from repro.rl.grpo import GRPOConfig

    w = _workload()
    cfg = dataclasses.replace(
        get_config("qwen2_5_7b").reduced(), num_layers=2, d_model=64,
        d_ff=128, num_heads=2, num_kv_heads=2, head_dim=32,
        vocab_size=ByteTokenizer.vocab_size)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rows = []

    # ---- fused: monolithic generate() through the legacy facade ----
    ref = jax.tree.map(lambda a: a.copy(), params)
    fused = AsyncRLRunner(
        WorkflowConfig(mode=w["mode"],
                       num_rollout_workers=w["rollout_workers"],
                       rollout_batch=w["rollout_batch"],
                       train_micro_batch=w["train_micro_batch"],
                       prompts_per_step=w["prompts_per_step"],
                       group_size=w["group_size"],
                       num_steps=w["num_steps"],
                       extra_columns=("ref_logprob",)),
        rollout_engine=JaxRolloutEngine(
            cfg, group_size=w["group_size"],
            max_new_tokens=w["max_new_tokens"], ref_params=ref),
        train_engine=JaxTrainEngine(
            cfg, params, rl=GRPOConfig(kl_coef=w["kl_coef"]),
            global_batch=w["prompts_per_step"] * w["group_size"],
            seq_len=w["seq_len"]),
        prompt_stream=lambda s: PromptDataset(seed=0).prompts_for_step(
            s, w["prompts_per_step"]))
    r_fused = fused.run()

    # ---- staged: the grpo stage-graph dataflow ----
    tcfg = TrainerConfig(
        mode=w["mode"], num_steps=w["num_steps"],
        prompts_per_step=w["prompts_per_step"],
        group_size=w["group_size"],
        rollout_workers=w["rollout_workers"],
        rollout_batch=w["rollout_batch"],
        train_micro_batch=w["train_micro_batch"],
        max_new_tokens=w["max_new_tokens"], seq_len=w["seq_len"],
        kl_coef=w["kl_coef"], seed=0)
    r_staged = Trainer(tcfg, model_cfg=cfg).fit()

    # ---- planner-sized: identical dataflow, every stage left at
    # num_workers=0 and auto-sized from the analytic cost model; the
    # elastic monitor may rebalance pools mid-run ----
    pcfg = dataclasses.replace(tcfg, rollout_workers=0,
                               auto_size_workers=True,
                               elastic_interval_s=0.2)
    r_planned = Trainer(pcfg, model_cfg=cfg).fit()

    for label, r in (("fused", r_fused), ("staged", r_staged),
                     ("planned", r_planned)):
        bf = r.bubble_fraction
        roll = [v for k, v in bf.items() if k.startswith("rollout")]
        rows.append(dict(name=f"stage_graph_{label}_rollout_bubble",
                         us_per_call=r.wall_time_s * 1e6,
                         derived=round(float(np.mean(roll)), 3)))
        rows.append(dict(name=f"stage_graph_{label}_train_bubble",
                         us_per_call=r.wall_time_s * 1e6,
                         derived=round(bf.get("train-0", 0.0), 3)))
        if render:
            print(f"--- {label}: wall {r.wall_time_s:.2f}s ---")
            print(r.log.render_gantt(100))
    return rows


def run_chaos(render: bool = False, smoke: bool = False) -> list[dict]:
    """Fault-injection arm: staged GRPO under 0% / 5% / 15% crash rates.

    Each arm runs in a scoped metrics registry so the row-accounting
    (produced vs trained vs requeued) is per-rate. Emits, per rate:
    throughput, replica restarts, rows requeued, and rows lost/duplicated
    (both must be 0 — recovery is exactly-once)."""
    import jax  # noqa: F401  (warm the backend before timing)

    from repro.api import Trainer, TrainerConfig
    from repro.configs import get_config
    from repro.core.obs import scoped
    from repro.core.supervision import FaultConfig
    from repro.data.tokenizer import ByteTokenizer

    w = _workload()
    cfg = dataclasses.replace(
        get_config("qwen2_5_7b").reduced(), num_layers=2, d_model=64,
        d_ff=128, num_heads=2, num_kv_heads=2, head_dim=32,
        vocab_size=ByteTokenizer.vocab_size)
    rates = (0.0, 0.05) if smoke else (0.0, 0.05, 0.15)
    num_steps = 2 if smoke else w["num_steps"]
    expected = num_steps * w["prompts_per_step"] * w["group_size"]
    rows = []

    def _make_cfg(p, steps):
        return TrainerConfig(
            mode=w["mode"], num_steps=steps,
            prompts_per_step=w["prompts_per_step"],
            group_size=w["group_size"],
            rollout_workers=w["rollout_workers"],
            rollout_batch=w["rollout_batch"],
            train_micro_batch=w["train_micro_batch"],
            max_new_tokens=w["max_new_tokens"], seq_len=w["seq_len"],
            kl_coef=w["kl_coef"], seed=0,
            heartbeat_timeout_s=30.0,
            max_replica_restarts=64,
            # seed 8 draws a crash on each initial worker's first
            # calls even at 5%, so every rate > 0 exercises recovery
            faults=FaultConfig(crash_p=p, seed=8,
                               stages=("generate",)) if p else None)

    # untimed full-length warmup so the first rate doesn't absorb JIT
    # compilation (a 1-step warmup leaves ~15% skew on the first arm)
    with scoped():
        Trainer(_make_cfg(0.0, num_steps), model_cfg=cfg).fit()
    for p in rates:
        with scoped() as reg:
            r = Trainer(_make_cfg(p, num_steps), model_cfg=cfg).fit()
            snap = reg.snapshot()

        def _total(name):
            return sum(v["value"]
                       for v in snap.get(name, {}).get("values", []))

        def _labeled(name, **want):
            return sum(v["value"]
                       for v in snap.get(name, {}).get("values", [])
                       if all(v.get("labels", {}).get(k) == lv
                              for k, lv in want.items()))

        produced = _labeled("stage_samples_total", stage="generate")
        restarts = _total("replica_restarts_total")
        requeued = _total("rows_requeued_total")
        tag = f"{int(p * 100)}pct"
        rows.append(dict(name=f"stage_graph_chaos_{tag}_throughput",
                         us_per_call=r.wall_time_s * 1e6,
                         derived=round(r.throughput, 2)))
        rows.append(dict(name=f"stage_graph_chaos_{tag}_restarts",
                         us_per_call=r.wall_time_s * 1e6,
                         derived=int(restarts)))
        rows.append(dict(name=f"stage_graph_chaos_{tag}_rows_requeued",
                         us_per_call=r.wall_time_s * 1e6,
                         derived=int(requeued)))
        # exactly-once accounting: every expected row trained, and the
        # generate stage never produced a duplicate
        rows.append(dict(name=f"stage_graph_chaos_{tag}_rows_lost",
                         us_per_call=r.wall_time_s * 1e6,
                         derived=int(expected - r.samples_trained)))
        rows.append(dict(name=f"stage_graph_chaos_{tag}_rows_duplicated",
                         us_per_call=r.wall_time_s * 1e6,
                         derived=int(produced - expected)))
        if render:
            print(f"--- crash_p={p}: wall {r.wall_time_s:.2f}s · "
                  f"{r.samples_trained}/{expected} rows · "
                  f"{int(restarts)} restarts · "
                  f"{int(requeued)} requeued ---")
    return rows


def run_chaos_trainer_kill(render: bool = False,
                           smoke: bool = False) -> list[dict]:
    """Trainer-kill arm: the staged GRPO workload with durable run
    snapshots on, the trainer deterministically killed at a mid-run
    step, and warm restart from the newest snapshot while the generate
    fleet keeps streaming. Asserts exactly-once row accounting (zero
    lost, zero duplicated) and reports the recovery wall-clock overhead
    against an identically-checkpointed clean run."""
    import tempfile

    import jax  # noqa: F401  (warm the backend before timing)

    from repro.api import Trainer, TrainerConfig
    from repro.configs import get_config
    from repro.core.obs import scoped
    from repro.core.supervision import FaultConfig
    from repro.data.tokenizer import ByteTokenizer

    w = _workload()
    cfg = dataclasses.replace(
        get_config("qwen2_5_7b").reduced(), num_layers=2, d_model=64,
        d_ff=128, num_heads=2, num_kv_heads=2, head_dim=32,
        vocab_size=ByteTokenizer.vocab_size)
    num_steps = 2 if smoke else w["num_steps"]
    expected = num_steps * w["prompts_per_step"] * w["group_size"]
    # actor_update sees samples_per_step/micro calls per step; kill at
    # the start of the run's middle step (ordinal = step * calls/step)
    calls_per_step = (w["prompts_per_step"] * w["group_size"]
                      // w["train_micro_batch"])
    kill_at = (num_steps // 2) * calls_per_step

    def _make_cfg(ckpt_dir, kill):
        return TrainerConfig(
            mode=w["mode"], num_steps=num_steps,
            prompts_per_step=w["prompts_per_step"],
            group_size=w["group_size"],
            rollout_workers=w["rollout_workers"],
            rollout_batch=w["rollout_batch"],
            train_micro_batch=w["train_micro_batch"],
            max_new_tokens=w["max_new_tokens"], seq_len=w["seq_len"],
            kl_coef=w["kl_coef"], seed=0, heartbeat_timeout_s=30.0,
            checkpoint_dir=ckpt_dir, checkpoint_interval_steps=1,
            faults=FaultConfig(seed=0, stages=("actor_update",),
                               crash_on_calls=(kill_at,))
            if kill else None)

    rows = []
    with tempfile.TemporaryDirectory() as tmp:
        # untimed warmup (JIT), then a clean checkpointed run as the
        # recovery-overhead baseline, then the killed run
        with scoped():
            Trainer(_make_cfg(f"{tmp}/warm", False), model_cfg=cfg).fit()
        with scoped():
            r_clean = Trainer(_make_cfg(f"{tmp}/clean", False),
                              model_cfg=cfg).fit()
        with scoped() as reg:
            r = Trainer(_make_cfg(f"{tmp}/kill", True),
                        model_cfg=cfg).fit()
            snap = reg.snapshot()

    def _total(name):
        return sum(v["value"] for v in snap.get(name, {}).get("values", []))

    def _labeled(name, **want):
        return sum(v["value"] for v in snap.get(name, {}).get("values", [])
                   if all(v.get("labels", {}).get(k) == lv
                          for k, lv in want.items()))

    produced = _labeled("stage_samples_total", stage="generate")
    restarts = _total("trainer_restarts_total")
    requeued = _total("rows_requeued_total")
    dup_dropped = _total("rows_dropped_duplicate_total")
    snaps = sum(v.get("count", 0) for v in
                snap.get("checkpoint_write_seconds", {}).get("values", []))
    overhead = (r.wall_time_s - r_clean.wall_time_s) / r_clean.wall_time_s
    us = r.wall_time_s * 1e6
    tag = "stage_graph_chaos_trainer_kill"
    rows.append(dict(name=f"{tag}_throughput", us_per_call=us,
                     derived=round(r.throughput, 2)))
    rows.append(dict(name=f"{tag}_restarts", us_per_call=us,
                     derived=int(restarts)))
    rows.append(dict(name=f"{tag}_snapshots", us_per_call=us,
                     derived=int(snaps)))
    rows.append(dict(name=f"{tag}_rows_requeued", us_per_call=us,
                     derived=int(requeued)))
    rows.append(dict(name=f"{tag}_dup_rows_dropped", us_per_call=us,
                     derived=int(dup_dropped)))
    rows.append(dict(name=f"{tag}_recovery_overhead_pct", us_per_call=us,
                     derived=round(100 * overhead, 1)))
    # exactly-once accounting across the trainer death: every expected
    # row trained exactly once, none regenerated
    rows.append(dict(name=f"{tag}_rows_lost", us_per_call=us,
                     derived=int(expected - r.samples_trained)))
    rows.append(dict(name=f"{tag}_rows_duplicated", us_per_call=us,
                     derived=int(produced - expected)))
    if render:
        print(f"--- trainer-kill @ call {kill_at}: "
              f"wall {r.wall_time_s:.2f}s (clean "
              f"{r_clean.wall_time_s:.2f}s, +{100 * overhead:.1f}%) · "
              f"{r.samples_trained}/{expected} rows · "
              f"{int(restarts)} trainer restarts · "
              f"{int(snaps)} snapshots ---")
    return rows


def main(argv=None) -> int:
    import argparse
    import json
    import time

    ap = argparse.ArgumentParser()
    ap.add_argument("--chaos", action="store_true",
                    help="run the fault-injection arm only")
    ap.add_argument("--kill-trainer", action="store_true",
                    help="with --chaos: kill + warm-restart the trainer")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced steps / rates for CI")
    ap.add_argument("--json", dest="json_path", default="",
                    help="write rows as a bench-trajectory JSON file")
    args = ap.parse_args(argv)
    if args.chaos and args.kill_trainer:
        rows = run_chaos_trainer_kill(render=True, smoke=args.smoke)
    elif args.chaos:
        rows = run_chaos(render=True, smoke=args.smoke)
    else:
        rows = run(render=True)
    for row in rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    if args.json_path:
        suite = "stage_graph"
        if args.chaos:
            suite = "chaos_trainer_kill" if args.kill_trainer else "chaos"
        doc = {"schema": "asyncflow-bench-trajectory/v1",
               "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                          time.gmtime()),
               "suites": {suite: {"rows": rows, "error": None}}}
        with open(args.json_path, "w") as fh:
            json.dump(doc, fh, indent=2, default=str)
            fh.write("\n")
    # fault-injection acceptance: recovery must be exactly-once
    bad = [r for r in rows
           if r["name"].endswith(("rows_lost", "rows_duplicated"))
           and r["derived"] != 0]
    if bad:
        for r in bad:
            print(f"FAIL {r['name']} = {r['derived']}")
        return 1
    return 0


if __name__ == "__main__":
    import sys

    if len(sys.argv) > 1:
        sys.exit(main())
    for row in run(render=True):
        print(row)
