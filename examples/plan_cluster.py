"""Resource planning (paper §4.3): search the best rollout/train split for
a target cluster and compare workflow modes at scale via the simulator.

  PYTHONPATH=src python examples/plan_cluster.py --chips 512 --arch qwen2_5_32b
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_config  # noqa: E402
from repro.core.planner import (ClusterPlan, Workload, plan_resources,  # noqa: E402
                                simulate)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chips", type=int, default=512)
    ap.add_argument("--arch", default="qwen2_5_32b")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    w = Workload(prompts_per_step=256, group_size=8, mean_response_len=2048,
                 num_steps=6)
    pr = plan_resources(cfg, args.chips, w, mode="separated_async")
    p = pr.plan
    print(f"cluster: {args.chips} chips, model: {cfg.name} "
          f"({cfg.param_count()/1e9:.0f}B)")
    print(f"best plan: rollout={p.rollout_chips} (TP{p.rollout_tp}) | "
          f"train={p.train_chips} (TP{p.train_tp})  "
          f"[{pr.candidates_scored} candidates scored]\n")

    print(f"{'mode':<18s} {'samples/s':>10s} {'trainer busy':>13s}")
    for mode in ("colocated", "separated", "separated_tq",
                 "separated_async"):
        plan = p if mode != "colocated" else ClusterPlan(
            args.chips, args.chips, args.chips, 4, 8,
            reshard_s=1.0 + 0.002 * args.chips)
        r = simulate(cfg, plan, w, mode)
        print(f"{mode:<18s} {r['throughput_samples_per_s']:>10.2f} "
              f"{r['trainer_busy_fraction']:>12.1%}")


if __name__ == "__main__":
    main()
