"""PPO on the same substrate ("under development" in the paper §6.1 —
complete here): actor + critic with GAE over the verifiable math task.

  PYTHONPATH=src python examples/ppo_quickstart.py --steps 8
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.data import PromptDataset  # noqa: E402
from repro.data.tokenizer import ByteTokenizer  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.rl import (PPOConfig, critic_forward, gae,  # noqa: E402
                      init_critic_params, math_reward, ppo_train_step)
from repro.rl.sampling import generate  # noqa: E402
from repro.training import OptimizerConfig, TrainState  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=4)
    args = ap.parse_args()

    tok = ByteTokenizer()
    cfg = dataclasses.replace(get_config("qwen2_5_7b").reduced(),
                              num_layers=2, d_model=64, d_ff=128,
                              num_heads=2, num_kv_heads=2, head_dim=32,
                              vocab_size=tok.vocab_size)
    actor = TrainState.create(init_params(jax.random.PRNGKey(0), cfg))
    critic = TrainState.create(init_critic_params(jax.random.PRNGKey(1), cfg))
    rl = PPOConfig(vf_coef=0.5)
    opt = OptimizerConfig(lr=5e-4, warmup_steps=2)
    ds = PromptDataset(seed=0)

    for step in range(args.steps):
        prompts = ds.prompts_for_step(step, args.batch)
        rows = generate(actor.params, cfg, [p["tokens"] for p in prompts],
                        step, max_new_tokens=args.max_new)
        S = max(len(r["tokens"]) for r in rows)
        tokens = np.stack([r["tokens"][:S] for r in rows])
        masks = np.stack([r["response_mask"][:S] for r in rows])
        old_lp = np.stack([r["logprobs"][:S] for r in rows])

        values = np.asarray(critic_forward(critic.params, cfg,
                                           jnp.asarray(tokens)))
        adv = np.zeros_like(values)
        rets = np.zeros_like(values)
        rewards = []
        for i, (p, r) in enumerate(zip(prompts, rows)):
            rew = math_reward(p["answer"], r["response_ids"])
            rewards.append(rew)
            idx = np.where(masks[i] > 0)[0]
            if len(idx) == 0:
                continue
            traj_r = np.zeros(len(idx), np.float32)
            traj_r[-1] = rew                       # terminal reward
            v = np.concatenate([values[i, idx], [0.0]])
            a, ret = gae(traj_r, v, gamma=1.0, lam=0.95)
            adv[i, idx] = a
            rets[i, idx] = ret

        batch = {"tokens": jnp.asarray(tokens),
                 "response_mask": jnp.asarray(masks),
                 "old_logprob": jnp.asarray(old_lp),
                 "advantage": jnp.asarray(adv),
                 "returns": jnp.asarray(rets),
                 "old_values": jnp.asarray(values)}
        actor, critic, metrics = ppo_train_step(actor, critic, cfg, rl, opt,
                                                batch)
        print(f"step {step:2d} reward {np.mean(rewards):+.3f} "
              f"policy_loss {float(metrics['policy_loss']):+.4f} "
              f"value_loss {float(metrics['value_loss']):.4f}")


if __name__ == "__main__":
    main()
