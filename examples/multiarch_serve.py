"""Serve batched requests on several architecture families through the
same rollout engine — dense (GQA), MLA, SSM (mamba), hybrid (RG-LRU).

  PYTHONPATH=src python examples/multiarch_serve.py
"""
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.data import PromptDataset  # noqa: E402
from repro.data.tokenizer import ByteTokenizer  # noqa: E402
from repro.models import count_params, init_params  # noqa: E402
from repro.rl.sampling import generate  # noqa: E402


def main():
    tok = ByteTokenizer()
    ds = PromptDataset(seed=0)
    prompts = [p["tokens"] for p in ds.prompts_for_step(0, 4)]

    for arch in ("qwen2_5_7b", "minicpm3_4b", "falcon_mamba_7b",
                 "recurrentgemma_9b"):
        cfg = dataclasses.replace(get_config(arch).reduced(),
                                  vocab_size=tok.vocab_size)
        params = init_params(jax.random.PRNGKey(0), cfg)
        t0 = time.time()
        rows = generate(params, cfg, prompts, 0, max_new_tokens=8,
                        temperature=0.8)
        dt = time.time() - t0
        n_tok = sum(len(r["response_ids"]) for r in rows)
        print(f"{arch:<20s} [{cfg.arch_type:>6s}] "
              f"params={count_params(params)/1e6:5.1f}M "
              f"{n_tok/dt:7.1f} tok/s  sample: "
              f"{tok.decode(rows[0]['response_ids'])!r}")


if __name__ == "__main__":
    main()
