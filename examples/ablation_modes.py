"""Table-1 ablation live: run the identical GRPO workload under the three
workflow modes and compare wall-clock throughput + bubbles.

  PYTHONPATH=src python examples/ablation_modes.py
"""
import sys
import time

sys.path.insert(0, "src")

from repro.api import Trainer, TrainerConfig  # noqa: E402


def main():
    def cfg(mode, steps=5):
        return TrainerConfig(arch="qwen2_5_7b", mode=mode, num_steps=steps,
                             prompts_per_step=4, group_size=2,
                             rollout_workers=2, rollout_batch=2,
                             train_micro_batch=2, max_new_tokens=6,
                             seq_len=24, channel_bandwidth_gbps=0.25)

    # warm the XLA compile cache so no mode is charged compilation
    print("warming up (compiling step functions)...")
    Trainer(cfg("streaming", 1)).fit()
    Trainer(cfg("baseline", 1)).fit()

    results = {}
    for mode in ("baseline", "streaming", "async"):
        t0 = time.time()
        r = Trainer(cfg(mode)).fit()
        results[mode] = (time.time() - t0, r)

    base = results["baseline"][1].throughput
    print(f"\n{'setting':<22s} {'throughput':>12s} {'normalized':>11s} "
          f"{'max stale':>10s}")
    labels = {"baseline": "Baseline", "streaming": "w/TransferQueue",
              "async": "2 + w/Asyn.Opt"}
    for mode, (wall, r) in results.items():
        print(f"{labels[mode]:<22s} {r.throughput:>9.2f}/s "
              f"{r.throughput/base:>10.2f}x {max(r.staleness_seen):>10d}")

    print("\nasync-mode timeline:")
    print(results["async"][1].log.render_gantt(90))


if __name__ == "__main__":
    main()
