"""Quickstart: end-to-end GRPO post-training with AsyncFlow on CPU.

Trains a small Qwen-style policy on verifiable arithmetic with the full
stack — TransferQueue streaming, async delayed parameter updates, GRPO —
and prints reward progress plus the execution Gantt chart.

  PYTHONPATH=src python examples/quickstart.py --steps 30
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.api import Trainer, TrainerConfig  # noqa: E402
from repro.core.obs import render_report  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--mode", default="async",
                    choices=["baseline", "streaming", "async"])
    ap.add_argument("--group-size", type=int, default=4)
    ap.add_argument("--lr", type=float, default=2e-3)
    args = ap.parse_args()

    tcfg = TrainerConfig(
        arch="qwen2_5_7b",            # reduced to CPU scale automatically
        mode=args.mode,
        num_steps=args.steps,
        prompts_per_step=4,
        group_size=args.group_size,
        rollout_workers=2,
        max_new_tokens=4,
        seq_len=16,
        lr=args.lr,
        reward="shaped",   # dense signal so learning is visible quickly
    )
    print(f"mode={args.mode} steps={args.steps} — training...")
    result = Trainer(tcfg).fit()

    print(f"\nwall time   : {result.wall_time_s:.1f}s")
    print(f"throughput  : {result.throughput:.1f} samples/s")
    print(f"max staleness seen: {max(result.staleness_seen)} "
          f"(bound: threshold+1 = {tcfg.staleness + 1})")
    print("\nreward curve (mean per step):")
    for m in result.metrics:
        r = m.get("mean_reward", float("nan"))
        bar = "#" * max(0, int((r + 0.2) * 30))
        print(f"  step {m['step']:3d}  reward {r:+.3f}  {bar}")
    print("\nexecution timeline (G=generate U=update w=weight-sync .=wait):")
    print(result.log.render_gantt(90))
    print()
    print(render_report(result.telemetry))


if __name__ == "__main__":
    main()
