"""Expert-parallel MoE with explicit all_to_all dispatch (shard_map).

The GSPMD path (``repro.models.moe.moe_ffn`` + sharding annotations) lets
XLA infer the dispatch collectives; this module is the production EP
implementation with the classic two-hop pattern made explicit:

  1. route: top-k experts per local token → destination device =
     expert // experts_per_device;
  2. dispatch: pack per-destination capacity buffers, ``all_to_all`` over
     the expert axis;
  3. local grouped FFN over the device's experts (capacity buffers, zero
     rows are harmless since the FFN has no biases);
  4. return ``all_to_all`` back to the source slots, weighted combine.

Capacity-based with drops (Switch-style) on both hops; token order is
restored exactly via the slot bookkeeping, so output == the dense oracle
up to dropped tokens (tested drop-free on small shapes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.layers import act_fn, dense


def _sort_dispatch(values, dest, n_dest, capacity):
    """Scatter ``values`` (M, d) into (n_dest, capacity, d) buffers by
    ``dest`` (M,) with per-destination positions. Returns (buffers,
    slot_dev, slot_pos, keep)."""
    M, d = values.shape
    order = jnp.argsort(dest)
    sorted_dest = dest[order]
    starts = jnp.searchsorted(sorted_dest, jnp.arange(n_dest))
    pos = jnp.arange(M) - starts[sorted_dest]
    keep = pos < capacity
    pos_c = jnp.where(keep, pos, 0)
    buf = jnp.zeros((n_dest, capacity, d), values.dtype)
    buf = buf.at[sorted_dest, pos_c].add(
        jnp.where(keep[:, None], values[order], jnp.zeros((), values.dtype)))
    # slot of flat item i (original order): invert the sort
    inv = jnp.argsort(order)
    return buf, sorted_dest[inv], pos_c[inv], keep[inv]


def ep_moe_ffn(p, x, cfg, *, mesh, ep_axis: str = "model",
               dp_axis: str = "data", capacity_factor: float = 2.0):
    """x: (B, S, d) sharded over ``dp_axis``; expert weights (E, d, f)
    sharded over ``ep_axis`` on dim 0. Returns y like x.

    Requires cfg.num_experts % mesh.shape[ep_axis] == 0.
    """
    E, k = cfg.num_experts, cfg.top_k
    ep = mesh.shape[ep_axis]
    assert E % ep == 0
    E_loc = E // ep
    d = cfg.d_model

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(None, None, None),                 # router w (replicated)
                  {"up": P(ep_axis, None, None),
                   "down": P(ep_axis, None, None),
                   **({"gate": P(ep_axis, None, None)}
                     if "gate" in p["experts"] else {})},
                  P(dp_axis, None, None)),             # x
        out_specs=P(dp_axis, None, None),
        check_rep=False)
    def _inner(router_w, experts, x):
        B, S, _ = x.shape
        N = B * S
        xf = x.reshape(N, d)
        cd = x.dtype

        logits = (xf.astype(jnp.float32) @ router_w[0]).astype(jnp.float32)
        probs = jax.nn.softmax(logits, -1)
        gates, eids = jax.lax.top_k(probs, k)          # (N, k) global ids
        gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)

        dest_dev = (eids // E_loc).reshape(-1)         # (N*k,)
        local_eid = (eids % E_loc).reshape(-1)
        token_of = jnp.repeat(jnp.arange(N), k)

        C = int(max(1, -(-N * k // ep) * capacity_factor))
        send_x, slot_dev, slot_pos, keep = _sort_dispatch(
            xf[token_of], dest_dev, ep, C)
        # ship the local expert id alongside (sentinel 0 + zero row is a
        # no-op through the bias-free FFN)
        eid_buf = jnp.zeros((ep, C), jnp.int32)
        eid_buf = eid_buf.at[slot_dev, slot_pos].set(
            jnp.where(keep, local_eid, 0))

        recv_x = jax.lax.all_to_all(send_x, ep_axis, 0, 0, tiled=False)
        recv_eid = jax.lax.all_to_all(eid_buf, ep_axis, 0, 0, tiled=False)
        rx = recv_x.reshape(ep * C, d)                 # tokens for MY experts
        re = recv_eid.reshape(ep * C)

        # local grouped FFN via a second capacity dispatch over E_loc
        C2 = int(max(1, -(-ep * C // E_loc)))
        ebuf, s2_dev, s2_pos, k2 = _sort_dispatch(rx, re, E_loc, C2)
        f = act_fn(cfg.activation)
        h = jnp.einsum("ecd,edf->ecf", ebuf.astype(cd),
                       experts["up"].astype(cd))
        if "gate" in experts:
            h = h * f(jnp.einsum("ecd,edf->ecf", ebuf.astype(cd),
                                 experts["gate"].astype(cd)))
        else:
            h = f(h)
        out_buf = jnp.einsum("ecf,efd->ecd", h, experts["down"].astype(cd))
        # back to the received-slot layout
        ry = jnp.where(k2[:, None], out_buf[s2_dev, s2_pos],
                       jnp.zeros((), cd))
        back = jax.lax.all_to_all(ry.reshape(ep, C, d), ep_axis, 0, 0,
                                  tiled=False)

        # combine at the source: read each flat item's slot, weight, add
        vals = back[slot_dev, slot_pos]
        vals = jnp.where(keep[:, None], vals, jnp.zeros((), cd))
        y = jnp.zeros((N, d), cd).at[token_of].add(
            vals * gates.reshape(-1)[:, None].astype(cd))
        return y.reshape(B, S, d)

    y = _inner(p["router"]["w"][None], p["experts"], x)
    if "shared" in p:
        from repro.models.layers import mlp
        y = y + mlp(p["shared"], x, cfg.activation, x.dtype)
    return y
