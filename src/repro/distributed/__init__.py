from repro.distributed.sharding import (batch_pspecs, cache_pspecs, dp_axes,
                                        param_spec, state_pspecs, to_named,
                                        tree_pspecs)

__all__ = ["param_spec", "tree_pspecs", "state_pspecs", "batch_pspecs",
           "cache_pspecs", "to_named", "dp_axes"]
