"""Distributed flash-decode: explicit shard_map partial-softmax combine.

HC3 shards the KV cache's *sequence* dim over the "model" axis. Under
plain GSPMD, XLA all-gathers K/V per layer; the production path computes
per-shard partial attention (m, l, acc) with the decode_attention
blockwise math and combines across shards with three small collectives —
O(B·H·hd) on the wire instead of O(B·S·kv·hd):

    m*   = max_shards m_i
    l*   = Σ_i l_i · exp(m_i − m*)
    out  = Σ_i acc_i · exp(m_i − m*) / l*
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30


def _partial_attention(q, k, v, valid):
    """Local partial softmax-attention over this shard's keys.

    q: (B,1,H,hd); k/v: (B,S_loc,KVH,hd); valid: (B,S_loc).
    Returns (m (B,H), l (B,H), acc (B,H,hd)) — unnormalized.
    """
    B, _, H, hd = q.shape
    KVH = k.shape[2]
    if KVH != H:
        k = jnp.repeat(k, H // KVH, axis=2)
        v = jnp.repeat(v, H // KVH, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5      # (B,H,S_loc)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    m = s.max(-1)                                           # (B,H)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, :], p, 0.0)                # kill exp(-inf-...)
    l = p.sum(-1)
    acc = jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32))
    return m, l, acc


def sharded_decode_attention(q, k_cache, v_cache, valid, *, mesh,
                             seq_axis: str = "model"):
    """One-token attention with the cache sequence dim sharded over
    ``seq_axis``. q replicated along that axis; returns (B,1,H,hd)."""

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(None, seq_axis, None, None),
                  P(None, seq_axis, None, None), P(None, seq_axis)),
        out_specs=P(),
        check_rep=False)
    def _inner(q, k, v, valid):
        m, l, acc = _partial_attention(q, k, v, valid)
        m_star = jax.lax.pmax(m, seq_axis)                  # (B,H)
        scale = jnp.exp(m - m_star)
        l_star = jax.lax.psum(l * scale, seq_axis)
        out = jax.lax.psum(acc * scale[..., None], seq_axis)
        out = out / jnp.maximum(l_star, 1e-30)[..., None]
        return out[:, None].astype(q.dtype)                 # (B,1,H,hd)

    return _inner(q, k_cache, v_cache, valid)
