"""Sharding rules: params / optimizer state / inputs → PartitionSpecs.

Scheme (Megatron-TP x FSDP, MaxText-style logical axes):
  * "model" axis — tensor parallel: attention heads, FFN hidden, vocab,
    MoE experts (expert parallel when num_experts % model == 0, else
    tensor-parallel expert FFN), mamba/rglru channel dims.
  * "data" axis  — batch data parallel + FSDP weight sharding (params and
    optimizer state shard their d_model-ish dim over "data"; XLA inserts
    the per-layer all-gathers).
  * "pod" axis   — pure data parallel across pods (multi-pod mesh);
    gradients all-reduce over it, parameters are NOT sharded over it.

Rules are path-pattern based so they cover every architecture in the zoo.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def param_spec(path: str, leaf, cfg, mesh: Mesh) -> P:
    """path: "/"-joined tree path, e.g. "blocks/attn/wq/w"."""
    shape = leaf.shape
    stacked = bool(re.match(
        r"^(blocks|dense_blocks|tiles|enc_blocks|dec_blocks)(/|$)", path)) \
        and len(shape) >= 1
    lead: tuple = (None,) if stacked else ()

    def spec(*axes) -> P:
        # drop axis names that don't divide the corresponding dim
        ax = list(axes)
        off = len(lead)
        for i, a in enumerate(ax):
            if a is None:
                continue
            dim = shape[off + i] if off + i < len(shape) else 0
            if not _div(dim, mesh, a):
                ax[i] = None
        return P(*lead, *ax)

    # ---- embeddings / heads -------------------------------------------------
    if path.endswith("embed/table"):
        return spec("model", "data")
    if path.endswith("lm_head/w"):
        return spec("data", "model")
    if "enc_pos" in path or "dec_pos" in path:
        return spec(None, None)

    # ---- norms / scalars -----------------------------------------------------
    if "/ln" in path or "norm" in path or path.endswith("lambda") \
            or path.endswith("d_skip") or path.endswith("conv_b"):
        return spec(*([None] * (len(shape) - len(lead))))

    # ---- MoE -------------------------------------------------------------------
    if "/experts/" in path:  # (E, d, dff) or (E, dff, d)
        E = shape[len(lead)]
        if _div(E, mesh, "model"):
            return spec("model", None, None)          # expert parallel
        if path.endswith("down"):
            return spec(None, "model", "data")        # TP experts
        return spec(None, "data", "model")
    if "/router/" in path:
        return spec("data", None)
    if "/shared/" in path:
        if path.endswith("down/w"):
            return spec("model", "data")
        return spec("data", "model")

    # ---- MLA --------------------------------------------------------------------
    if path.endswith("w_dkv/w") or path.endswith("w_krope/w") \
            or path.endswith("w_dq/w"):
        return spec("data", None)
    if path.endswith("w_uk/w") or path.endswith("w_uv/w") \
            or path.endswith("w_uq/w"):
        return spec(None, "model")
    if path.endswith("w_q/w"):
        return spec("data", "model")

    # ---- attention -----------------------------------------------------------------
    if re.search(r"/(wq|wk|wv)/w$", path):
        return spec("data", "model")
    if re.search(r"/(wq|wk|wv)/b$", path):
        return spec("model")
    if path.endswith("wo/w"):
        return spec("model", "data")
    if path.endswith("wo/b"):
        return spec(None)

    # ---- MLP --------------------------------------------------------------------------
    if re.search(r"/(up|gate)/w$", path):
        return spec("data", "model")
    if path.endswith("down/w"):
        return spec("model", "data")

    # ---- mamba -------------------------------------------------------------------------
    if path.endswith("in_proj/w"):
        return spec("data", "model")
    if path.endswith("conv_w"):
        return spec(None, "model")
    if path.endswith("x_proj/w"):
        return spec("model", None)
    if path.endswith("dt_proj/w"):
        return spec(None, "model")
    if path.endswith("dt_proj/b"):
        return spec("model")
    if path.endswith("a_log"):
        return spec("model", None)
    if path.endswith("out_proj/w") or path.endswith("out/w"):
        return spec("model", "data")

    # ---- rglru ---------------------------------------------------------------------------
    if re.search(r"/(in_x|in_z)/w$", path):
        return spec("data", "model")
    if re.search(r"/(gate_a|gate_x)/w$", path):
        return spec(None, "model")

    # ---- fallback: replicate ----------------------------------------------------------------
    return spec(*([None] * (len(shape) - len(lead))))


def tree_pspecs(tree, cfg, mesh: Mesh):
    """Pytree of PartitionSpecs matching ``tree`` (params or a like-shaped
    optimizer-moment tree)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    for kpath, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kpath)
        if leaf.ndim == 0:
            specs.append(P())
        else:
            specs.append(param_spec(path, leaf, cfg, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def state_pspecs(state_shape, cfg, mesh: Mesh):
    """Shardings for a TrainState(params, {"m","v","count"}, step)."""
    p = tree_pspecs(state_shape.params, cfg, mesh)
    return type(state_shape)(
        params=p,
        opt_state={"m": tree_pspecs(state_shape.opt_state["m"], cfg, mesh),
                   "v": tree_pspecs(state_shape.opt_state["v"], cfg, mesh),
                   "count": P()},
        step=P())


# ---------------------------------------------------------------------------
# input rules
# ---------------------------------------------------------------------------

def batch_pspecs(batch_shape, cfg, mesh: Mesh, *, batch_sharded=True):
    """Training/prefill batch: leading dim is global batch."""
    dp = dp_axes(mesh) if batch_sharded else None

    def one(k, leaf):
        nd = leaf.ndim
        if nd == 0:
            return P()
        return P(dp, *([None] * (nd - 1)))

    return {k: one(k, v) for k, v in batch_shape.items()}


def cache_pspecs(cache_shape, cfg, mesh: Mesh, *, batch: int,
                 kv_seq_shard: bool = False):
    """Decode KV/state caches. Layout conventions (leading layer axis):
      gqa  k/v      (L, B, S, kv, hd)
      mla  c_kv     (L, B, S, r), k_rope (L, B, S, dr)
      ssm  h        (L, B, di, ds), conv (L, B, kc-1, di)
      hybrid rec h  (Lr, B, w), conv (Lr, B, 3, w); att as gqa

    batch > 1  → B over dp axes; batch == 1 (long_500k) → the sequence dim
    (gqa/mla) shards over "data" instead.
    """
    dp = dp_axes(mesh)
    b_ax = dp if batch > 1 and batch % int(np.prod(
        [mesh.shape[a] for a in (dp if isinstance(dp, tuple) else (dp,))]
    )) == 0 else None

    def one(path, leaf):
        nd = leaf.ndim
        last = path.rsplit("/", 1)[-1]
        if last in ("k", "v") or "cross_" in path:
            # (L, B, S, kv, hd)
            kv = leaf.shape[3]
            kv_ax = "model" if _div(kv, mesh, "model") else None
            s_ax = "data" if (b_ax is None and
                              _div(leaf.shape[2], mesh, "data")) else None
            if kv_ax is None and kv_seq_shard and s_ax != "model" \
                    and _div(leaf.shape[2], mesh, "model"):
                s_ax = "model"   # flash-decode style seq sharding (HC3)
            return P(None, b_ax, s_ax, kv_ax, None)
        if path.endswith("c_kv") or path.endswith("k_rope"):
            s_ax = "data" if (b_ax is None and
                              _div(leaf.shape[2], mesh, "data")) else None
            if kv_seq_shard and s_ax is None \
                    and _div(leaf.shape[2], mesh, "model"):
                s_ax = "model"
            return P(None, b_ax, s_ax, None)
        if path.endswith("/h") or path == "h":
            if nd == 4:   # ssm (L,B,di,ds)
                return P(None, b_ax,
                         "model" if _div(leaf.shape[2], mesh, "model")
                         else None, None)
            return P(None, b_ax,
                     "model" if _div(leaf.shape[2], mesh, "model") else None)
        if path.endswith("conv"):
            return P(None, b_ax, None,
                     "model" if _div(leaf.shape[3], mesh, "model") else None)
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    specs = []
    for kpath, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kpath)
        specs.append(one(path, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
