"""Byte-level tokenizer with a few specials — self-contained (offline)."""
from __future__ import annotations

from typing import List

import numpy as np

PAD, BOS, EOS = 0, 1, 2
N_SPECIALS = 3


class ByteTokenizer:
    """ids = byte value + N_SPECIALS; vocab_size = 256 + 3."""

    vocab_size = 256 + N_SPECIALS
    pad_id, bos_id, eos_id = PAD, BOS, EOS

    def encode(self, text: str, add_bos: bool = True,
               add_eos: bool = False) -> np.ndarray:
        ids = [b + N_SPECIALS for b in text.encode("utf-8")]
        if add_bos:
            ids = [BOS] + ids
        if add_eos:
            ids = ids + [EOS]
        return np.asarray(ids, np.int32)

    def decode(self, ids) -> str:
        bs = bytes(int(i) - N_SPECIALS for i in ids
                   if int(i) >= N_SPECIALS)
        return bs.decode("utf-8", errors="replace")

    def pad_batch(self, seqs: List[np.ndarray], length: int | None = None,
                  left: bool = False) -> tuple[np.ndarray, np.ndarray]:
        """Returns (tokens (B, L), mask (B, L))."""
        L = length or max(len(s) for s in seqs)
        B = len(seqs)
        out = np.full((B, L), PAD, np.int32)
        mask = np.zeros((B, L), np.float32)
        for i, s in enumerate(seqs):
            s = s[:L]
            if left:
                out[i, L - len(s):] = s
                mask[i, L - len(s):] = 1
            else:
                out[i, :len(s)] = s
                mask[i, :len(s)] = 1
        return out, mask
