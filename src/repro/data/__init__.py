from repro.data.dataset import MathDataset, MathSample, PromptDataset
from repro.data.tokenizer import ByteTokenizer

__all__ = ["ByteTokenizer", "MathDataset", "MathSample", "PromptDataset"]
