"""Synthetic DeepScaleR-like dataset: verifiable math QA.

The paper trains on DeepScaleR (AIME/AMC math problems with checkable
answers). Offline, we generate arithmetic problems with exact integer
answers — the same *system shape*: prompt -> sampled response ->
rule-verifiable reward.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List

import numpy as np

from repro.data.tokenizer import ByteTokenizer


@dataclasses.dataclass
class MathSample:
    prompt: str
    answer: int


class MathDataset:
    """Streaming arithmetic problems: ``a <op> b =``."""

    def __init__(self, seed: int = 0, max_operand: int = 9,
                 ops: str = "+-"):
        self.rng = np.random.default_rng(seed)
        self.max_operand = max_operand
        self.ops = ops

    def sample(self) -> MathSample:
        a = int(self.rng.integers(0, self.max_operand + 1))
        b = int(self.rng.integers(0, self.max_operand + 1))
        op = self.ops[int(self.rng.integers(0, len(self.ops)))]
        ans = a + b if op == "+" else a - b
        return MathSample(prompt=f"{a}{op}{b}=", answer=ans)

    def batch(self, n: int) -> List[MathSample]:
        return [self.sample() for _ in range(n)]

    def __iter__(self) -> Iterator[MathSample]:
        while True:
            yield self.sample()


class PromptDataset:
    """Tokenized prompt stream for the RL runner."""

    def __init__(self, tokenizer: ByteTokenizer | None = None, seed: int = 0,
                 max_operand: int = 9):
        self.tok = tokenizer or ByteTokenizer()
        self.ds = MathDataset(seed, max_operand)

    def prompts_for_step(self, step: int, n: int) -> List[dict]:
        # deterministic per step for reproducibility across workflow modes
        ds = MathDataset(seed=step * 7919 + 13, max_operand=self.ds.max_operand)
        out = []
        for s in ds.batch(n):
            out.append({"tokens": self.tok.encode(s.prompt),
                        "text": s.prompt, "answer": s.answer})
        return out
