"""Backend-level interface (paper §5.2, Code 2).

``RLAdapter`` is the low-level abstraction of RL tasks: each backend
(our JAX engines here; MindSpeed/vLLM/FSDP in the paper) implements the
same task verbs, so the algorithm layer never touches engine internals.
"""
from __future__ import annotations

import abc
from typing import Any, Dict, List


class RLAdapter(abc.ABC):
    """Abstraction of RL tasks over a training/inference backend."""

    # -- inference-side tasks -------------------------------------------------
    def generate_sequences(self, prompts: List[Any], **kw):
        raise NotImplementedError

    def compute_log_prob(self, batch: Dict[str, Any], **kw):
        raise NotImplementedError

    def compute_values(self, batch: Dict[str, Any], **kw):
        raise NotImplementedError

    def compute_rewards(self, batch: Dict[str, Any], **kw):
        raise NotImplementedError

    # -- training-side tasks ---------------------------------------------------
    def update_actor(self, batch: Dict[str, Any], **kw):
        raise NotImplementedError

    def update_critic(self, batch: Dict[str, Any], **kw):
        raise NotImplementedError

    # -- weights ---------------------------------------------------------------
    def get_weights(self):
        raise NotImplementedError

    def load_weights(self, weights) -> None:
        raise NotImplementedError


class EngineRegistry:
    """Engine plug-in point: industrial users register custom backends
    without touching the algorithm layer (paper §5)."""

    _registry: Dict[str, type] = {}

    @classmethod
    def register(cls, name: str):
        def deco(klass):
            cls._registry[name] = klass
            return klass
        return deco

    @classmethod
    def create(cls, name: str, *a, **kw) -> RLAdapter:
        if name not in cls._registry:
            raise KeyError(f"unknown engine {name!r}; "
                           f"registered: {list(cls._registry)}")
        return cls._registry[name](*a, **kw)
