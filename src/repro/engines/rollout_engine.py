"""JAX rollout engine — the inference-cluster backend.

Implements the inference-side ``RLAdapter`` verbs as separately-streamed
stage-graph tasks (paper §3.3 / §5.2):

* ``generate_sequences`` — sample G responses per prompt with the
  KV-cache decode loop and emit one experience row per sample (columns:
  response / logprob / response_mask / response_ids / group / answer).
  With ``chunk_tokens`` set it runs partial rollout (k1.5-style, §4.2.1):
  each call advances every sequence by at most ``chunk_tokens`` tokens and
  unfinished sequences are handed back as *continuations* that re-enter
  TransferQueue and resume on a later call — possibly under newer weights
  (sub-step asynchrony). Behavior logprobs of already-generated tokens
  are preserved verbatim (the behavior policy is the chunk-wise mixture,
  exactly what old_logprob must record).
* ``compute_log_prob`` — the reference-inference task: per-token frozen
  reference logprobs for the KL penalty.
* ``compute_rewards`` — the reward/advantage task: rule-based rewards per
  row plus (for GRPO) group-relative advantages, emitted as deferred
  writes once every member of a group has streamed through.

The fused ``generate``/``generate_chunked`` entry points (generation +
reference + reward + advantage in one call) remain as the legacy
two-task protocol used by ``AsyncRLRunner`` and the fused-vs-staged
benchmarks; they are thin compositions of the staged verbs above.
"""
from __future__ import annotations

import threading
from typing import List, Optional

import numpy as np

from repro.engines.adapter import EngineRegistry, RLAdapter
from repro.rl.advantage import grpo_advantages
from repro.rl.reward import math_reward
from repro.rl.sampling import generate as sample_generate


@EngineRegistry.register("jax_rollout")
class JaxRolloutEngine(RLAdapter):
    def __init__(self, cfg, *, group_size: int = 4, max_new_tokens: int = 8,
                 temperature: float = 1.0, reward_fn=math_reward,
                 ref_params=None, chunk_tokens: int = 0,
                 backend: str = "fixed", cb_slots: int = 4,
                 cb_page_size: int = 8, cb_max_len: int = 0,
                 cb_seed: int = 0, use_pallas: bool = False, mesh=None):
        """ref_params: frozen reference policy — enables the
        ``compute_log_prob`` reference-inference task (per-token ref
        logprobs for the KL penalty).

        chunk_tokens > 0 enables partial rollout (see module docstring).

        backend="continuous" routes sampling through the
        ``engines/continuous_batching`` subsystem (slot scheduler + paged
        KV cache): finished sequences stream out per-sample, and chunked
        continuations resume from their cached KV pages instead of
        re-prefilling the whole prefix. Sampling there is keyed per
        (cb_seed, sequence, position), so trajectories are independent of
        batch composition — fused and staged runs match by construction."""
        if backend not in ("fixed", "continuous"):
            raise ValueError(f"unknown rollout backend {backend!r}")
        self.cfg = cfg
        self.group_size = group_size
        self.max_new_tokens = max_new_tokens
        self.temperature = temperature
        self.reward_fn = reward_fn
        self.ref_params = ref_params
        self.chunk_tokens = chunk_tokens
        self.backend = backend
        self.cb_slots = cb_slots
        self.cb_page_size = cb_page_size
        self.cb_max_len = cb_max_len
        self.cb_seed = cb_seed
        self.use_pallas = use_pallas
        self.mesh = mesh
        self._cb = None                  # lazy ContinuousBatchingEngine
        self._groups: dict = {}          # fused path: gid -> finished members
        self._reward_groups: dict = {}   # staged path: gid -> (member, idx, r)
        self._glock = threading.Lock()
        self._gid = 0
        # cold resume: a run snapshot's rollout cursor sets these bases so
        # a resumed run continues the (cb_seed, uid, pos)-keyed sampling
        # stream exactly where the uninterrupted run would be
        self.cb_uid_start = 0

    def _new_gid(self) -> int:
        with self._glock:
            self._gid += 1
            return self._gid

    # ------------------------------------------------------------------ #
    # staged verbs (stage-graph tasks)                                    #
    # ------------------------------------------------------------------ #

    def _sample_rows(self, params, prompts: List[dict], rng, *,
                     version: int = 0, emit=None) -> List[dict]:
        """Sample prompts x G; one staged experience row per sample (no
        reward/advantage — those stream through their own stages)."""
        if self.backend == "continuous":
            return self._sample_rows_cb(params, prompts, version=version,
                                        emit=emit)
        G = self.group_size
        flat = [p["tokens"] for p in prompts for _ in range(G)]
        seed = int(rng.integers(0, 2**31 - 1))
        outs = sample_generate(params, self.cfg, flat, seed,
                               max_new_tokens=self.max_new_tokens,
                               temperature=self.temperature)
        rows = []
        for pi, p in enumerate(prompts):
            gid = self._new_gid()
            for m in range(G):
                o = outs[pi * G + m]
                rows.append(dict(
                    prompt=p, response=o["tokens"], logprob=o["logprobs"],
                    response_mask=o["response_mask"],
                    response_ids=o["response_ids"],
                    group=(gid, m, G), answer=p["answer"],
                    token_len=int(o["response_mask"].sum())))
        if emit is not None:
            for r in rows:
                emit(r)
            return []
        return rows

    # ------------------------------------------------------------------ #
    # continuous-batching backend                                         #
    # ------------------------------------------------------------------ #

    def _cb_engine(self, need_len: int):
        """Lazy continuous-batching engine, rebuilt (uid space preserved)
        if a longer prompt+budget arrives than the current max_len; parked
        continuations survive a rebuild by re-prefilling on resume."""
        from repro.engines.continuous_batching import \
            ContinuousBatchingEngine
        with self._glock:
            eng = self._cb
            if eng is None or need_len > eng.max_len:
                self._cb = ContinuousBatchingEngine(
                    self.cfg, num_slots=self.cb_slots,
                    page_size=self.cb_page_size,
                    max_len=max(need_len, self.cb_max_len,
                                eng.max_len if eng else 0),
                    max_new_tokens=self.max_new_tokens,
                    temperature=self.temperature, seed=self.cb_seed,
                    uid_start=self.cb_uid_start if eng is None
                    else eng._next_uid,
                    use_pallas=self.use_pallas, mesh=self.mesh)
            return self._cb

    def _member_from_seq(self, q) -> dict:
        """Finished/paused CB Sequence -> chunked member dict (the same
        shape ``_member_row`` / ``_emit_finished_groups`` consume)."""
        return {"_cont": True, "gid": q.meta["gid"],
                "member": q.meta["member"], "prompt": q.meta["prompt"],
                "tokens": np.asarray(q.tokens),
                "logprobs": np.asarray(q.logprobs, np.float32),
                "gen_len": q.gen_len, "versions": list(q.versions),
                "_cb_seq": q}

    def _sample_rows_cb(self, params, prompts: List[dict], *,
                        version: int = 0, emit=None) -> List[dict]:
        """One-shot sampling through the continuous batcher: slots admit
        prompt×G members FIFO, finished rows stream out per-sample."""
        G = self.group_size
        need = max(len(p["tokens"]) for p in prompts) + self.max_new_tokens
        eng = self._cb_engine(need)
        seqs = []
        for p in prompts:
            gid = self._new_gid()
            for m in range(G):
                seqs.append(eng.make_sequence(
                    p["tokens"], meta=dict(prompt=p, gid=gid, member=m)))
        to_row = lambda q: self._member_row(self._member_from_seq(q),
                                            chunked=False)
        if emit is not None:
            eng.generate(params, seqs, version=version,
                         emit=lambda q: emit(to_row(q)))
            return []
        fin, _ = eng.generate(params, seqs, version=version)
        fin.sort(key=lambda q: q.uid)    # restore prompt×G block order
        return [to_row(q) for q in fin]

    def _advance_chunks_cb(self, params, items: List[dict], *,
                           version: int = 0, emit=None):
        """Partial rollout on the paged KV cache: a continuation carries
        its live ``Sequence`` (``_cb_seq``) whose KV pages stay parked in
        the pool between chunks — resuming costs no re-prefill unless the
        pages were preempted under pool pressure."""
        C = self.chunk_tokens or self.max_new_tokens
        G = self.group_size
        need = self.max_new_tokens
        for it in items:
            if it.get("_cont"):
                q = it["_cb_seq"]
                need = max(need, q.prompt_len + q.max_new)
            else:
                need = max(need, len(it["tokens"]) + self.max_new_tokens)
        eng = self._cb_engine(need)
        seqs = []
        for it in items:
            if it.get("_cont"):
                seqs.append(eng.resume(it["_cb_seq"], chunk=C))
            else:
                gid = self._new_gid()
                for m in range(G):
                    seqs.append(eng.make_sequence(
                        it["tokens"], chunk=C,
                        meta=dict(prompt=it, gid=gid, member=m)))
        emit_cb = None if emit is None else \
            (lambda q: emit(self._member_from_seq(q)))
        fin, paused = eng.generate(params, seqs, version=version,
                                   emit=emit_cb)
        fin.sort(key=lambda q: q.uid)
        finished = [] if emit is not None else \
            [self._member_from_seq(q) for q in fin]
        return finished, [self._member_from_seq(q) for q in paused]

    def generate_sequences(self, batch, *, params, rng, version: int = 0,
                           emit=None, heartbeat=None, **kw):
        """Stage verb: batch["prompt"] -> {"rows": [...], "requeue": [...]}.

        Chunked engines emit each finished group member immediately — the
        downstream reward stage owns group completion, so members stream
        out without waiting for their group.  With the continuous backend
        an ``emit`` callback receives each finished row the moment its
        sequence completes (per-sample handoff into the TransferQueue);
        emitted rows are excluded from the returned batch.

        ``heartbeat`` (supervised fleets) is pinged per emitted sample so
        a long rollout is never mistaken for a hung replica."""
        prompts = batch["prompt"]
        if heartbeat is not None:
            heartbeat()
            if emit is not None:
                inner = emit
                emit = lambda row: (heartbeat(), inner(row))[1]
        if self.chunk_tokens:
            row_emit = None if emit is None else \
                (lambda s: emit(self._member_row(s)))
            finished, conts = self._advance_chunks(params, prompts, rng,
                                                   version=version,
                                                   emit=row_emit)
            return {"rows": [self._member_row(s) for s in finished],
                    "requeue": conts}
        return {"rows": self._sample_rows(params, prompts, rng,
                                          version=version, emit=emit)}

    def _ref_logprobs(self, responses, params=None) -> List[np.ndarray]:
        """Per-token logprobs of the frozen reference over full sequences
        (position 0 gets 0.0 — no prediction for the first token)."""
        import jax.numpy as jnp

        from repro.models import forward
        from repro.rl.loss import token_logprobs
        params = self.ref_params if params is None else params
        arrs = [np.asarray(t) for t in responses]
        S = max(len(a) for a in arrs)
        toks = np.zeros((len(arrs), S), np.int32)
        for i, a in enumerate(arrs):
            toks[i, :len(a)] = a
        logits, _ = forward(params, self.cfg, {"tokens": jnp.asarray(toks)})
        lp, _ = token_logprobs(logits[:, :-1], toks[:, 1:])
        lp = np.asarray(lp)
        return [np.concatenate([[0.0], lp[i, :len(a) - 1]]).astype(
            np.float32) for i, a in enumerate(arrs)]

    def compute_log_prob(self, batch, *, params=None, **kw):
        """Stage verb (reference inference): writes ``ref_logprob``."""
        return {"updates": {"ref_logprob":
                            self._ref_logprobs(batch["response"],
                                               params=params)}}

    def compute_rewards(self, batch, *, indices=None,
                        group_advantage: bool = True, **kw):
        """Stage verb: rule-based reward per row; with ``group_advantage``
        (GRPO) also buffers rewards per group and emits group-relative
        advantages as deferred writes once all G members streamed in."""
        rewards = [float(self.reward_fn(a, rid))
                   for a, rid in zip(batch["answer"], batch["response_ids"])]
        out = {"updates": {"reward": rewards}}
        if not group_advantage:
            return out
        writes = []
        with self._glock:
            for idx, g, r in zip(indices, batch["group"], rewards):
                gid, member, G = g
                buf = self._reward_groups.setdefault(gid, [])
                buf.append((member, idx, r))
                if len(buf) == G:
                    buf.sort()
                    advs = np.asarray(grpo_advantages(
                        np.asarray([b[2] for b in buf], np.float32)))
                    writes += [(i, "advantage", float(a))
                               for (_, i, _), a in zip(buf, advs)]
                    del self._reward_groups[gid]
        out["writes"] = writes
        return out

    # ------------------------------------------------------------------ #
    # fused legacy protocol (AsyncRLRunner / fused-vs-staged benchmark)   #
    # ------------------------------------------------------------------ #

    def generate(self, params, prompts: List[dict], rng) -> List[dict]:
        """Fused: generation + reference + reward + advantage in one call.
        prompts: [{"tokens": np.ndarray, "answer": int, ...}] ->
        one row per (prompt x G) sample."""
        rows = self._sample_rows(params, prompts, rng)
        ref_lps = self._ref_logprobs([r["response"] for r in rows]) \
            if self.ref_params is not None else None
        G = self.group_size
        for gi in range(0, len(rows), G):
            group = rows[gi:gi + G]
            rewards = np.asarray([self.reward_fn(r["answer"],
                                                 r["response_ids"])
                                  for r in group], np.float32)
            advs = np.asarray(grpo_advantages(rewards))
            for j, (r, rew, a) in enumerate(zip(group, rewards, advs)):
                r["reward"] = float(rew)
                r["advantage"] = float(a)
                if ref_lps is not None:
                    r["ref_logprob"] = ref_lps[gi + j]
        return rows

    # -- partial rollout (paper §4.2.1 / k1.5) ------------------------------

    def _advance_chunks(self, params, items: List[dict], rng, *,
                        version: int = 0, emit=None):
        """items: fresh prompt dicts or continuation dicts (``_cont``).
        Advances every sequence by at most ``chunk_tokens`` tokens.
        Returns (finished_members, continuations); with ``emit`` every
        finished member is delivered through the callback instead and the
        returned finished list is empty."""
        if self.backend == "continuous":
            return self._advance_chunks_cb(params, items, version=version,
                                           emit=emit)
        C = self.chunk_tokens or self.max_new_tokens
        seqs = []
        for it in items:
            if it.get("_cont"):
                seqs.append(it)
            else:  # fresh prompt -> spawn G group members
                gid = self._new_gid()
                for m in range(self.group_size):
                    seqs.append({"_cont": True, "gid": gid, "member": m,
                                 "prompt": it,
                                 "tokens": np.asarray(it["tokens"]),
                                 "logprobs": np.zeros(len(it["tokens"]),
                                                      np.float32),
                                 "gen_len": 0, "versions": []})
        if not seqs:
            return [], []

        seed = int(rng.integers(0, 2**31 - 1))
        outs = sample_generate(params, self.cfg,
                               [s["tokens"] for s in seqs], seed,
                               max_new_tokens=C,
                               temperature=self.temperature)
        finished_members, continuations = [], []
        from repro.data.tokenizer import ByteTokenizer
        eos = ByteTokenizer.eos_id
        for s, o in zip(seqs, outs):
            start = len(s["tokens"])
            new_toks = np.asarray(o["tokens"][start:start + C])
            new_lps = np.asarray(o["logprobs"][start:start + C])
            # truncate at EOS within the chunk
            hits = np.where(new_toks == eos)[0]
            n_new = int(hits[0]) + 1 if len(hits) else len(new_toks)
            s = dict(s)
            s["tokens"] = np.concatenate([s["tokens"], new_toks[:n_new]])
            s["logprobs"] = np.concatenate([s["logprobs"], new_lps[:n_new]])
            s["gen_len"] += n_new
            s["versions"] = s["versions"] + [version]
            done = len(hits) > 0 or s["gen_len"] >= self.max_new_tokens
            if done:
                finished_members.append(s)
            else:
                continuations.append(s)
        if emit is not None:
            for s in finished_members:
                emit(s)
            finished_members = []
        return finished_members, continuations

    def _member_row(self, s: dict, *, chunked: bool = True) -> dict:
        """Finished chunked member -> staged experience row."""
        p = s["prompt"]
        plen = len(np.asarray(p["tokens"]))
        toks = np.asarray(s["tokens"])
        mask = np.zeros(len(toks), np.float32)
        mask[plen:] = 1.0
        row = dict(prompt=p, response=toks, logprob=s["logprobs"],
                   response_mask=mask, response_ids=toks[plen:],
                   group=(s["gid"], s["member"], self.group_size),
                   answer=p["answer"], token_len=int(s["gen_len"]))
        if chunked:
            row["chunk_versions"] = s["versions"]
        return row

    def generate_chunked(self, params, items: List[dict], rng, *,
                         version: int = 0):
        """Fused chunked path: group advantages are emitted only once every
        member of a group has finished. Returns (rows, continuations)."""
        finished, conts = self._advance_chunks(params, items, rng,
                                               version=version)
        return self._emit_finished_groups(finished), conts

    def _emit_finished_groups(self, members: List[dict]) -> List[dict]:
        """Buffer finished members per group; once all G are in, compute
        group advantages and emit experience rows."""
        complete = []
        with self._glock:
            for s in members:
                buf = self._groups.setdefault(s["gid"], [])
                buf.append(s)
                if len(buf) == self.group_size:
                    complete.append(self._groups.pop(s["gid"]))
        rows = []
        for group in complete:
            p = group[0]["prompt"]
            plen = len(np.asarray(p["tokens"]))
            rewards = np.asarray(
                [self.reward_fn(p["answer"], s["tokens"][plen:])
                 for s in group], np.float32)
            advs = np.asarray(grpo_advantages(rewards))
            for s, r, a in zip(group, rewards, advs):
                mask = np.zeros(len(s["tokens"]), np.float32)
                mask[plen:] = 1.0
                rows.append(dict(
                    prompt=p, response=s["tokens"], logprob=s["logprobs"],
                    response_mask=mask, reward=float(r), advantage=float(a),
                    token_len=int(s["gen_len"]),
                    chunk_versions=s["versions"]))
        return rows
