"""JAX rollout engine — the inference-cluster backend.

Implements the AsyncRLRunner producer protocol: ``generate(params,
prompts, rng)`` samples G responses per prompt with the KV-cache decode
loop, scores them with the rule-based reward, computes GRPO group
advantages, and returns one experience row per sample (the columns the
actor_update task consumes through TransferQueue).

**Partial rollout** (k1.5-style, paper §4.2.1): with ``chunk_tokens`` set,
each generate() call advances every sequence by at most ``chunk_tokens``
tokens; unfinished sequences are handed back as *continuations* that
re-enter TransferQueue and resume on a later call — possibly under newer
weights (sub-step asynchrony). Behavior logprobs of already-generated
tokens are preserved verbatim (the behavior policy is the chunk-wise
mixture, exactly what old_logprob must record); GRPO group advantages are
emitted only once every member of a group has finished.
"""
from __future__ import annotations

import threading
from typing import List

import numpy as np

from repro.engines.adapter import EngineRegistry, RLAdapter
from repro.rl.advantage import grpo_advantages
from repro.rl.reward import math_reward
from repro.rl.sampling import generate as sample_generate


@EngineRegistry.register("jax_rollout")
class JaxRolloutEngine(RLAdapter):
    def __init__(self, cfg, *, group_size: int = 4, max_new_tokens: int = 8,
                 temperature: float = 1.0, reward_fn=math_reward,
                 ref_params=None, chunk_tokens: int = 0):
        """ref_params: frozen reference policy — when set, the engine also
        runs the *reference inference* RL task (per-token ref logprobs for
        the KL penalty), adding the third task of the paper's GRPO+KL
        dataflow through TransferQueue.

        chunk_tokens > 0 enables partial rollout (see module docstring)."""
        self.cfg = cfg
        self.group_size = group_size
        self.max_new_tokens = max_new_tokens
        self.temperature = temperature
        self.reward_fn = reward_fn
        self.ref_params = ref_params
        self.chunk_tokens = chunk_tokens
        self._groups: dict = {}          # group id -> finished members
        self._glock = threading.Lock()
        self._gid = 0

    # AsyncRLRunner protocol -------------------------------------------------
    def generate(self, params, prompts: List[dict], rng) -> List[dict]:
        """prompts: [{"tokens": np.ndarray, "answer": int, ...}] ->
        one row per (prompt x G) sample."""
        G = self.group_size
        flat = [p["tokens"] for p in prompts for _ in range(G)]
        seed = int(rng.integers(0, 2**31 - 1))
        outs = sample_generate(params, self.cfg, flat, seed,
                               max_new_tokens=self.max_new_tokens,
                               temperature=self.temperature)
        ref_lps = None
        if self.ref_params is not None:
            import jax.numpy as jnp

            from repro.models import forward
            from repro.rl.loss import token_logprobs
            toks = jnp.asarray(np.stack([o["tokens"] for o in outs]))
            logits, _ = forward(self.ref_params, self.cfg, {"tokens": toks})
            lp, _ = token_logprobs(logits[:, :-1], toks[:, 1:])
            ref_lps = np.concatenate(
                [np.zeros((lp.shape[0], 1), np.float32), np.asarray(lp)], 1)
        rows = []
        for pi, p in enumerate(prompts):
            group = outs[pi * G:(pi + 1) * G]
            rewards = np.asarray([self.reward_fn(p["answer"],
                                                 o["response_ids"])
                                  for o in group], np.float32)
            advs = np.asarray(grpo_advantages(rewards))
            for gi, (o, r, a) in enumerate(zip(group, rewards, advs)):
                row = dict(
                    prompt=p, response=o["tokens"],
                    logprob=o["logprobs"],
                    response_mask=o["response_mask"],
                    reward=float(r), advantage=float(a),
                    token_len=int(o["response_mask"].sum()))
                if ref_lps is not None:
                    row["ref_logprob"] = ref_lps[pi * G + gi]
                rows.append(row)
        return rows

    def generate_sequences(self, prompts, **kw):
        raise RuntimeError("use generate(params, prompts, rng)")

    # -- partial rollout (paper §4.2.1 / k1.5) ------------------------------

    def _new_gid(self) -> int:
        with self._glock:
            self._gid += 1
            return self._gid

    def generate_chunked(self, params, items: List[dict], rng, *,
                         version: int = 0):
        """items: fresh prompt dicts or continuation dicts (``_cont``).
        Returns (finished_rows, continuations). Each call advances every
        sequence by at most ``chunk_tokens`` tokens."""
        C = self.chunk_tokens or self.max_new_tokens
        seqs = []
        for it in items:
            if it.get("_cont"):
                seqs.append(it)
            else:  # fresh prompt -> spawn G group members
                gid = self._new_gid()
                for m in range(self.group_size):
                    seqs.append({"_cont": True, "gid": gid, "member": m,
                                 "prompt": it,
                                 "tokens": np.asarray(it["tokens"]),
                                 "logprobs": np.zeros(len(it["tokens"]),
                                                      np.float32),
                                 "gen_len": 0, "versions": []})
        if not seqs:
            return [], []

        seed = int(rng.integers(0, 2**31 - 1))
        outs = sample_generate(params, self.cfg,
                               [s["tokens"] for s in seqs], seed,
                               max_new_tokens=C,
                               temperature=self.temperature)
        finished_members, continuations = [], []
        from repro.data.tokenizer import ByteTokenizer
        eos = ByteTokenizer.eos_id
        for s, o in zip(seqs, outs):
            start = len(s["tokens"])
            new_toks = np.asarray(o["tokens"][start:start + C])
            new_lps = np.asarray(o["logprobs"][start:start + C])
            # truncate at EOS within the chunk
            hits = np.where(new_toks == eos)[0]
            n_new = int(hits[0]) + 1 if len(hits) else len(new_toks)
            s = dict(s)
            s["tokens"] = np.concatenate([s["tokens"], new_toks[:n_new]])
            s["logprobs"] = np.concatenate([s["logprobs"], new_lps[:n_new]])
            s["gen_len"] += n_new
            s["versions"] = s["versions"] + [version]
            done = len(hits) > 0 or s["gen_len"] >= self.max_new_tokens
            if done:
                finished_members.append(s)
            else:
                continuations.append(s)

        rows = self._emit_finished_groups(finished_members)
        return rows, continuations

    def _emit_finished_groups(self, members: List[dict]) -> List[dict]:
        """Buffer finished members per group; once all G are in, compute
        group advantages and emit experience rows."""
        complete = []
        with self._glock:
            for s in members:
                buf = self._groups.setdefault(s["gid"], [])
                buf.append(s)
                if len(buf) == self.group_size:
                    complete.append(self._groups.pop(s["gid"]))
        rows = []
        for group in complete:
            p = group[0]["prompt"]
            plen = len(np.asarray(p["tokens"]))
            rewards = np.asarray(
                [self.reward_fn(p["answer"], s["tokens"][plen:])
                 for s in group], np.float32)
            advs = np.asarray(grpo_advantages(rewards))
            for s, r, a in zip(group, rewards, advs):
                mask = np.zeros(len(s["tokens"]), np.float32)
                mask[plen:] = 1.0
                rows.append(dict(
                    prompt=p, response=s["tokens"], logprob=s["logprobs"],
                    response_mask=mask, reward=float(r), advantage=float(a),
                    token_len=int(s["gen_len"]),
                    chunk_versions=s["versions"]))
        return rows
