"""Slot-based decode scheduler — the control plane of continuous batching.

A fixed pool of decode slots is the unit of batching: every decode step
advances all occupied slots by one token, and the moment a sequence
finishes (EOS / token budget) its slot frees and the next waiting prompt
is admitted — no per-batch lockstep on the slowest sequence.

The scheduler is deliberately pure Python / numpy-free: slot state,
strict-FIFO admission fairness and per-sequence bookkeeping live here so
they can be tested without touching JAX; the engine owns all device
compute.
"""
from __future__ import annotations

import itertools
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional


@dataclass
class Sequence:
    """One in-flight request: prompt + everything generated so far.

    ``tokens``/``logprobs`` are plain lists while in flight (appended one
    token per decode step); the engine materializes arrays on emit.
    ``kv pages`` are owned by ``uid`` in the PagedKVPool, not stored here.
    """
    uid: int
    prompt_len: int
    tokens: List[int]
    logprobs: List[float]
    max_new: int                      # total new-token budget
    meta: dict = field(default_factory=dict)   # gid/member/prompt row, ...
    gen_len: int = 0                  # new tokens generated so far
    chunk_left: int = 0               # remaining budget this chunk (0 = off)
    versions: List[int] = field(default_factory=list)
    eos: bool = False
    admitted_at: int = -1             # admission sequence number (fairness)

    @property
    def length(self) -> int:
        return len(self.tokens)

    @property
    def done(self) -> bool:
        return self.eos or self.gen_len >= self.max_new

    @property
    def paused(self) -> bool:
        """Chunk budget exhausted but the sequence itself is unfinished."""
        return (not self.done) and self.chunk_left == 0 and \
            bool(self.versions)


class SlotScheduler:
    """Fixed decode-slot pool with a strict-FIFO waiting queue.

    ``admit`` enqueues; ``take_admissions`` hands out (slot, sequence)
    pairs for every free slot in admission order — the fairness contract
    is that no later arrival ever overtakes an earlier one into a slot.
    """

    def __init__(self, num_slots: int):
        if num_slots < 1:
            raise ValueError("need at least one decode slot")
        self.num_slots = int(num_slots)
        self.slots: List[Optional[Sequence]] = [None] * self.num_slots
        self.waiting: Deque[Sequence] = deque()
        self._uid_slot: Dict[int, int] = {}
        self._admit_counter = itertools.count()
        self._lock = threading.Lock()
        self.admissions_total = 0

    # -- queue side --------------------------------------------------------

    def admit(self, seq: Sequence) -> None:
        with self._lock:
            seq.admitted_at = next(self._admit_counter)
            self.waiting.append(seq)

    def take_admissions(self) -> List[tuple]:
        """Pop waiting sequences into free slots (FIFO) and return the new
        ``(slot, sequence)`` assignments. Deferred admissions (e.g. KV
        pool exhausted) are pushed back with :meth:`defer`."""
        out = []
        with self._lock:
            for s in range(self.num_slots):
                if self.slots[s] is None and self.waiting:
                    seq = self.waiting.popleft()
                    self.slots[s] = seq
                    self._uid_slot[seq.uid] = s
                    self.admissions_total += 1
                    out.append((s, seq))
        return out

    def defer(self, slot: int, seq: Sequence) -> None:
        """Undo an assignment from :meth:`take_admissions` (put the
        sequence back at the *front* of the queue — FIFO is preserved)."""
        with self._lock:
            self.slots[slot] = None
            self._uid_slot.pop(seq.uid, None)
            self.admissions_total -= 1
            self.waiting.appendleft(seq)

    def requeue_front(self, seq: Sequence) -> None:
        """Push an evicted sequence back to the head of the queue (it was
        admitted earliest among waiters, so FIFO order is preserved)."""
        with self._lock:
            self.waiting.appendleft(seq)

    # -- slot side ---------------------------------------------------------

    def release(self, slot: int) -> Optional[Sequence]:
        """Free a slot (finished or paused sequence); returns it."""
        with self._lock:
            seq = self.slots[slot]
            self.slots[slot] = None
            if seq is not None:
                self._uid_slot.pop(seq.uid, None)
            return seq

    def active(self) -> List[tuple]:
        """[(slot, sequence)] for every occupied slot."""
        with self._lock:
            return [(s, q) for s, q in enumerate(self.slots)
                    if q is not None]

    def slot_of(self, uid: int) -> Optional[int]:
        with self._lock:
            return self._uid_slot.get(uid)

    @property
    def num_active(self) -> int:
        with self._lock:
            return sum(q is not None for q in self.slots)

    @property
    def num_waiting(self) -> int:
        with self._lock:
            return len(self.waiting)

    @property
    def occupancy(self) -> float:
        return self.num_active / self.num_slots

    @property
    def idle(self) -> bool:
        with self._lock:
            return not self.waiting and all(q is None for q in self.slots)
