"""Continuous-batching rollout subsystem: slot scheduler, paged KV
cache, and disaggregated prefill/decode dispatch (AsyncFlow §3.3)."""
from repro.engines.continuous_batching.engine import (
    ContinuousBatchingEngine, SUPPORTED_ARCHS)
from repro.engines.continuous_batching.paged_kv import (KVPoolExhausted,
                                                        PagedKVPool)
from repro.engines.continuous_batching.scheduler import (Sequence,
                                                         SlotScheduler)

__all__ = [
    "ContinuousBatchingEngine",
    "KVPoolExhausted",
    "PagedKVPool",
    "Sequence",
    "SlotScheduler",
    "SUPPORTED_ARCHS",
]
