"""Paged KV cache — block-allocated KV pages for continuous batching.

The physical cache is one page pool per K/V tensor, shaped
``(L, num_pages, page_size, KVH, hd)``.  A sequence owns an ordered list
of pages (allocated on demand as it grows, freed as one unit when it
finishes), so a prefix is prefilled exactly once and then decoded
incrementally — no per-chunk re-prefill — and a finished sequence's
memory is immediately reusable by a waiting prompt.

Ownership is keyed by *sequence id*, not decode slot: a partial-rollout
continuation can release its decode slot between chunks while its pages
stay parked, and resume later from the cached prefix.

Physical page 0 is reserved as a scratch/garbage page: the batched decode
step always writes one KV row per slot, and idle slots (plus page-table
padding) point at page 0 so those writes land harmlessly outside any
live sequence.
"""
from __future__ import annotations

import threading
from typing import Dict, List

import numpy as np


class KVPoolExhausted(RuntimeError):
    """No free pages left — admission must wait for a release."""


class PagedKVPool:
    """Block allocator + physical storage for per-sequence KV pages.

    Parameters
    ----------
    cfg: model config (num_layers / num_kv_heads / head_dim).
    num_pages: physical pages in the pool (page 0 is reserved).
    page_size: tokens per page.
    pages_per_seq: page-table width — the max pages one sequence may own
        (``page_size * pages_per_seq`` is the max sequence length).
    """

    def __init__(self, cfg, *, num_pages: int, page_size: int,
                 pages_per_seq: int, dtype=None):
        import jax.numpy as jnp
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        self.cfg = cfg
        self.page_size = int(page_size)
        self.pages_per_seq = int(pages_per_seq)
        self.num_pages = int(num_pages)
        dtype = jnp.bfloat16 if dtype is None else dtype
        shape = (cfg.num_layers, num_pages, page_size, cfg.num_kv_heads,
                 cfg.head_dim)
        self.k = jnp.zeros(shape, dtype)
        self.v = jnp.zeros(shape, dtype)
        self._lock = threading.Lock()
        # page 0 reserved: idle decode slots scatter their dummy KV row
        # there, so it must never belong to a live sequence
        self._free: List[int] = list(range(1, num_pages))
        self._owned: Dict[int, List[int]] = {}     # seq uid -> page ids
        self.kv_len: Dict[int, int] = {}           # seq uid -> tokens cached

    # -- allocation --------------------------------------------------------

    @property
    def free_pages(self) -> int:
        with self._lock:
            return len(self._free)

    @property
    def pages_in_use(self) -> int:
        with self._lock:
            return sum(len(p) for p in self._owned.values())

    def owns(self, uid: int) -> bool:
        with self._lock:
            return uid in self._owned

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(int(n_tokens), 1) // self.page_size)

    def ensure(self, uid: int, n_tokens: int) -> None:
        """Grow ``uid``'s page list to cover ``n_tokens`` positions.

        Raises :class:`KVPoolExhausted` (allocating nothing) if the pool
        cannot satisfy the request — callers either defer admission or
        surface a configuration error.
        """
        need = self.pages_for(n_tokens)
        if need > self.pages_per_seq:
            raise ValueError(
                f"sequence needs {need} pages > pages_per_seq="
                f"{self.pages_per_seq} (page_size={self.page_size})")
        with self._lock:
            owned = self._owned.setdefault(uid, [])
            self.kv_len.setdefault(uid, 0)
            grow = need - len(owned)
            if grow <= 0:
                return
            if grow > len(self._free):
                if not owned:
                    del self._owned[uid]
                    del self.kv_len[uid]
                raise KVPoolExhausted(
                    f"need {grow} pages, {len(self._free)} free "
                    f"(pool={self.num_pages}, page_size={self.page_size})")
            for _ in range(grow):
                owned.append(self._free.pop())

    def release(self, uid: int) -> None:
        """Return every page owned by ``uid`` to the free list."""
        with self._lock:
            pages = self._owned.pop(uid, [])
            self.kv_len.pop(uid, None)
            self._free.extend(pages)

    def page_row(self, uid: int) -> np.ndarray:
        """``uid``'s page table row, padded with the reserved page 0."""
        row = np.zeros(self.pages_per_seq, np.int32)
        with self._lock:
            for i, p in enumerate(self._owned.get(uid, [])):
                row[i] = p
        return row

    # -- prefill write -----------------------------------------------------

    def write_prefill(self, uid: int, k_seq, v_seq, n_tokens: int) -> None:
        """Store a prefilled prefix: ``k_seq``/``v_seq`` are
        ``(L, S, KVH, hd)`` with the first ``n_tokens`` rows valid.
        Allocates pages on demand; one scatter per touched page."""
        self.ensure(uid, n_tokens)
        ps = self.page_size
        with self._lock:
            pages = list(self._owned[uid])
        k, v = self.k, self.v
        for j in range(self.pages_for(n_tokens)):
            lo = j * ps
            n = min(ps, n_tokens - lo)
            k = k.at[:, pages[j], :n].set(k_seq[:, lo:lo + n])
            v = v.at[:, pages[j], :n].set(v_seq[:, lo:lo + n])
        self.k, self.v = k, v
        with self._lock:
            self.kv_len[uid] = max(self.kv_len.get(uid, 0), n_tokens)
