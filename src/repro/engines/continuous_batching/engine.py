"""Continuous-batching rollout engine: slot scheduler + paged KV cache +
disaggregated prefill/decode dispatch on one device.

Two dispatch paths share the model:

* **prefill** — waiting prompts are admitted into free decode slots in
  padded length-buckets and run through the full-sequence forward once
  (``return_cache=True``); the prompt KV lands in block-allocated pages
  and the first response token is sampled from the prefill logits.
* **decode** — one jitted step advances *every* occupied slot by one
  token against its paged KV (gather pages -> ``decode_step`` -> scatter
  the one written row back). ``use_pallas=True`` routes the inner
  attention through ``kernels/decode_attention``; passing a ``mesh``
  routes it through ``distributed/flash_decode``'s partial-softmax
  combine.

The moment a sequence finishes it is emitted (per-sample handoff — no
batch barrier), its pages and slot free, and the next waiting prompt is
admitted.  Partial rollout parks a paused sequence's pages between
chunks, so a continuation resumes from its cached prefix instead of
re-prefilling it (falling back to one prefill if its pages were
preempted under pool pressure).

Sampling uses a counter-based per-sequence PRNG — token ``i`` of
sequence ``uid`` is always drawn with ``fold_in(fold_in(key, uid), i)``
— so trajectories do not depend on slot assignment or batch composition.
"""
from __future__ import annotations

import functools
import threading
import time
from typing import Callable, Dict, List, Optional
from typing import Sequence as SeqList

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.obs import get_registry
from repro.data.tokenizer import ByteTokenizer
from repro.engines.continuous_batching.paged_kv import (KVPoolExhausted,
                                                        PagedKVPool)
from repro.engines.continuous_batching.scheduler import (Sequence,
                                                         SlotScheduler)
from repro.models import decode_step, forward

SUPPORTED_ARCHS = ("dense", "moe")


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _fold_keys(base_key, uids, positions):
    """(B,) per-sequence counter keys: fold_in(fold_in(key, uid), pos)."""
    return jax.vmap(lambda u, p: jax.random.fold_in(
        jax.random.fold_in(base_key, u), p))(uids, positions)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "temperature", "use_pallas"))
def _prefill_step(params, cfg, toks, lens, uids, base_key, *,
                  temperature: float, use_pallas: bool):
    """Bucketed prefill: one full forward over right-padded prompts
    yields KV for every prompt position plus the first sampled response
    token per row. Returns (k (L,B,S,KVH,hd), v, next_tok (B,), lp (B,))."""
    logits, _, cache = forward(params, cfg, {"tokens": toks},
                               use_pallas=use_pallas, return_cache=True)
    last = jnp.take_along_axis(
        logits, (lens - 1)[:, None, None], axis=1)[:, 0]      # (B, V)
    lt = last.astype(jnp.float32) / max(temperature, 1e-6)
    logp = jax.nn.log_softmax(lt, axis=-1)
    nxt = jax.vmap(jax.random.categorical)(
        _fold_keys(base_key, uids, lens), lt)
    lp = jnp.take_along_axis(logp, nxt[:, None], axis=1)[:, 0]
    if "dense_kv" in cache:            # moe: first_dense_layers prepended
        k = jnp.concatenate([cache["dense_kv"]["k"], cache["kv"]["k"]], 0)
        v = jnp.concatenate([cache["dense_kv"]["v"], cache["kv"]["v"]], 0)
    else:
        k, v = cache["kv"]["k"], cache["kv"]["v"]
    return k, v, nxt, lp


@functools.partial(jax.jit,
                   static_argnames=("cfg", "page_size", "temperature",
                                    "use_pallas", "mesh"))
def _decode_round_step(params, cfg, k_pool, v_pool, page_table, pos, tok,
                       uids, base_key, *, page_size: int,
                       temperature: float, use_pallas: bool, mesh):
    """One continuous-batching decode step over every slot.

    Gathers each slot's pages into a dense per-slot view, runs the
    one-token ``decode_step`` (which writes the new KV row at ``pos``),
    scatters that single row back into the page pool, and samples the
    next token per slot with its counter-based key.  Idle slots carry
    page-table rows of zeros, so their dummy writes land in the reserved
    scratch page 0."""
    L, _, ps, KVH, hd = k_pool.shape
    B, PPS = page_table.shape
    S = PPS * ps
    k_view = k_pool[:, page_table].reshape(L, B, S, KVH, hd)
    v_view = v_pool[:, page_table].reshape(L, B, S, KVH, hd)
    logits, new_cache = decode_step(params, cfg,
                                    {"k": k_view, "v": v_view}, tok, pos,
                                    use_pallas=use_pallas, mesh=mesh)
    bidx = jnp.arange(B)
    phys = page_table[bidx, pos // page_size]                 # (B,)
    off = pos % page_size
    k_pool = k_pool.at[:, phys, off].set(new_cache["k"][:, bidx, pos])
    v_pool = v_pool.at[:, phys, off].set(new_cache["v"][:, bidx, pos])

    lt = logits.astype(jnp.float32) / max(temperature, 1e-6)
    logp = jax.nn.log_softmax(lt, axis=-1)
    nxt = jax.vmap(jax.random.categorical)(
        _fold_keys(base_key, uids, pos + 1), lt)
    lp = jnp.take_along_axis(logp, nxt[:, None], axis=1)[:, 0]
    return k_pool, v_pool, nxt, lp


class ContinuousBatchingEngine:
    """Slot-based streaming generation over a paged KV cache.

    Parameters
    ----------
    cfg: model config (dense / moe GQA archs).
    num_slots: decode-slot pool size (the decode batch dimension).
    page_size: tokens per KV page.
    max_len: max total sequence length (prompt + generation); rounded up
        to a page multiple — fixes the decode attention window.
    num_pages: physical page-pool size; the default gives every slot its
        full page budget plus 50% headroom for parked continuations.
    max_new_tokens / temperature / eos_id: sampling policy defaults.
    seed: base of the counter-based sampling PRNG.
    uid_start: first sequence id — lets a caller rebuild the engine
        (e.g. to grow max_len) without colliding with earlier uids,
        keeping every sequence's sampling stream stable.
    use_pallas: dispatch decode attention to ``kernels/decode_attention``
        (and prefill attention to ``kernels/flash_attention``).
    mesh: optional device mesh — decode attention goes through
        ``distributed/flash_decode``'s sharded partial-softmax combine.
    """

    def __init__(self, cfg, *, num_slots: int = 4, page_size: int = 8,
                 max_len: int = 64, num_pages: Optional[int] = None,
                 max_new_tokens: int = 8, temperature: float = 1.0,
                 eos_id: int = ByteTokenizer.eos_id, seed: int = 0,
                 uid_start: int = 0, dtype=None, use_pallas: bool = False,
                 mesh=None, metrics=None):
        if cfg.arch_type not in SUPPORTED_ARCHS or cfg.attention == "mla":
            raise ValueError(
                f"continuous batching supports GQA {SUPPORTED_ARCHS} archs "
                f"(got arch_type={cfg.arch_type!r}, "
                f"attention={cfg.attention!r})")
        self.cfg = cfg
        self.page_size = int(page_size)
        self.max_len = -(-int(max_len) // self.page_size) * self.page_size
        pages_per_seq = self.max_len // self.page_size
        if num_pages is None:
            budget = num_slots * pages_per_seq
            num_pages = 1 + budget + budget // 2
        self.pool = PagedKVPool(cfg, num_pages=num_pages,
                                page_size=self.page_size,
                                pages_per_seq=pages_per_seq, dtype=dtype)
        self.scheduler = SlotScheduler(num_slots)
        self.num_slots = int(num_slots)
        self.max_new_tokens = int(max_new_tokens)
        self.temperature = float(temperature)
        self.eos_id = int(eos_id)
        self.use_pallas = bool(use_pallas)
        self.mesh = mesh
        self._base_key = jax.random.PRNGKey(seed)
        self._next_uid = int(uid_start)
        self._parked: Dict[int, Sequence] = {}
        self._lock = threading.Lock()

        m = metrics if metrics is not None else get_registry()
        self._registry = m
        self._g_occupancy = m.gauge(
            "rollout_slot_occupancy",
            "fraction of decode slots occupied").labels(engine="cb")
        self._g_pages = m.gauge(
            "rollout_kv_pages_in_use",
            "KV pages currently allocated").labels(engine="cb")
        self._h_prefill = m.histogram(
            "rollout_prefill_seconds",
            "prefill dispatch latency per bucket").labels(engine="cb")
        self._h_decode = m.histogram(
            "rollout_decode_step_seconds",
            "one continuous-batching decode step").labels(engine="cb")
        self._c_admit = m.counter(
            "rollout_admissions_total",
            "prompts admitted into decode slots").labels(engine="cb")
        self._c_preempt = m.counter(
            "rollout_preemptions_total",
            "sequences evicted under KV-pool pressure").labels(engine="cb")

    # ------------------------------------------------------------------ #
    # request construction                                                #
    # ------------------------------------------------------------------ #

    def make_sequence(self, tokens, *, max_new: Optional[int] = None,
                      chunk: int = 0, meta: Optional[dict] = None
                      ) -> Sequence:
        toks = [int(t) for t in np.asarray(tokens).tolist()]
        max_new = self.max_new_tokens if max_new is None else int(max_new)
        if len(toks) + max_new > self.max_len:
            raise ValueError(
                f"prompt ({len(toks)}) + max_new ({max_new}) exceeds "
                f"engine max_len={self.max_len}")
        uid, self._next_uid = self._next_uid, self._next_uid + 1
        return Sequence(uid=uid, prompt_len=len(toks),
                        tokens=toks, logprobs=[0.0] * len(toks),
                        max_new=max_new, meta=dict(meta or {}),
                        chunk_left=int(chunk) or max_new)

    def resume(self, seq: Sequence, *, chunk: int = 0) -> Sequence:
        """Re-arm a paused continuation for its next chunk."""
        seq.chunk_left = int(chunk) or (seq.max_new - seq.gen_len)
        return seq

    # ------------------------------------------------------------------ #
    # the scheduling loop                                                 #
    # ------------------------------------------------------------------ #

    def generate(self, params, items: SeqList[Sequence], *,
                 version: int = 0,
                 emit: Optional[Callable[[Sequence], None]] = None):
        """Run every item to completion or chunk-pause.

        Returns ``(finished, paused)`` lists of :class:`Sequence`; with
        ``emit`` each finished sequence is handed off the moment it
        completes (per-sample streaming), before the call returns."""
        with self._lock:
            return self._generate_locked(params, list(items), version,
                                         emit)

    def _generate_locked(self, params, items, version, emit):
        sched = self.scheduler
        for seq in items:
            seq.versions.append(version)
            self._parked.pop(seq.uid, None)
            sched.admit(seq)
        finished: List[Sequence] = []
        paused: List[Sequence] = []
        while not sched.idle:
            admitted = self._admit_and_prefill(params)
            if sched.num_active == 0:
                if admitted == 0 and sched.num_waiting:
                    raise RuntimeError(
                        "KV pool exhausted and nothing to preempt: "
                        f"{self.pool.free_pages} pages free — raise "
                        f"num_pages or lower num_slots/max_len")
                continue
            self._decode_one_round(params, finished, paused, emit)
        self._g_occupancy.set(0.0)
        self._g_pages.set(self.pool.pages_in_use)
        return finished, paused

    # -- admission / prefill dispatch --------------------------------------

    def _admit_and_prefill(self, params) -> int:
        """Move waiting sequences into free slots (strict FIFO); prefill
        fresh prefixes in padded length-buckets. Returns #admitted."""
        assigns = self.scheduler.take_admissions()
        if not assigns:
            return 0
        ok: List[tuple] = []
        deferred = False
        for slot, seq in assigns:
            if deferred:        # keep FIFO: nothing overtakes a deferral
                self.scheduler.defer(slot, seq)
                continue
            if not self._reserve_pages(seq):
                self.scheduler.defer(slot, seq)
                deferred = True
                continue
            ok.append((slot, seq))
        if not ok:
            return 0
        self._c_admit.inc(len(ok))
        need_prefill = [
            (s, q) for s, q in ok
            if self.pool.kv_len.get(q.uid, 0) < q.length - 1
            or q.gen_len == 0]
        buckets: Dict[int, List[tuple]] = {}
        for s, q in need_prefill:
            buckets.setdefault(((q.length + 7) // 8) * 8, []).append((s, q))
        for pad_len, group in sorted(buckets.items()):
            self._prefill_bucket(params, group, pad_len)
        self._g_occupancy.set(self.scheduler.occupancy)
        self._g_pages.set(self.pool.pages_in_use)
        return len(ok)

    def _reserve_pages(self, seq: Sequence) -> bool:
        """Ensure ``seq`` owns pages for its current prefix, preempting
        parked continuations under pool pressure."""
        while True:
            try:
                if not self.pool.owns(seq.uid):
                    self.pool.ensure(seq.uid, seq.length)
                return True
            except KVPoolExhausted:
                if not self._evict_parked():
                    return False

    def _evict_parked(self) -> bool:
        """Free the youngest parked continuation's pages (it re-prefills
        on resume — its sampled trajectory is unchanged)."""
        if not self._parked:
            return False
        uid = max(self._parked)        # youngest admission
        self.pool.release(uid)
        del self._parked[uid]
        self._c_preempt.inc()
        return True

    def _prefill_bucket(self, params, group: List[tuple], pad_len: int):
        """One prefill dispatch: right-padded prompts of similar length,
        batch padded to a power of two for compile-shape reuse."""
        t0 = time.monotonic()
        n_real = len(group)
        B = _next_pow2(n_real)
        toks = np.zeros((B, pad_len), np.int32)
        lens = np.ones(B, np.int32)
        uids = np.zeros(B, np.int32)
        for i, (_, q) in enumerate(group):
            toks[i, :q.length] = q.tokens
            lens[i] = q.length
            uids[i] = q.uid
        k, v, nxt, lp = _prefill_step(
            params, self.cfg, jnp.asarray(toks), jnp.asarray(lens),
            jnp.asarray(uids), self._base_key,
            temperature=self.temperature, use_pallas=self.use_pallas)
        k = k.astype(self.pool.k.dtype)
        v = v.astype(self.pool.v.dtype)
        nxt, lp = np.asarray(nxt), np.asarray(lp)
        for i, (_, q) in enumerate(group):
            self.pool.write_prefill(q.uid, k[:, i], v[:, i], q.length)
            self._append_token(q, int(nxt[i]), float(lp[i]))
        self._h_prefill.observe(time.monotonic() - t0)

    # -- decode dispatch ---------------------------------------------------

    def _append_token(self, seq: Sequence, tok: int, lp: float) -> None:
        seq.tokens.append(tok)
        seq.logprobs.append(lp)
        seq.gen_len += 1
        seq.chunk_left -= 1
        if tok == self.eos_id:
            seq.eos = True

    def _decode_one_round(self, params, finished, paused, emit) -> None:
        """Advance every occupied slot one token; retire/park finishers."""
        active = [(s, q) for s, q in self.scheduler.active()
                  if not (q.done or q.paused)]
        stepping = []
        for s, q in active:
            try:
                self.pool.ensure(q.uid, q.length)  # page-boundary growth
            except KVPoolExhausted:
                if self._evict_parked():
                    self.pool.ensure(q.uid, q.length)
                else:
                    # self-evict: drop this prefix's pages and requeue it
                    # at the front — it re-prefills once space frees
                    self.scheduler.release(s)
                    self.pool.release(q.uid)
                    self.scheduler.requeue_front(q)
                    self._c_preempt.inc()
                    continue
            stepping.append((s, q))
        if not stepping:
            self._retire(finished, paused, emit)
            return
        t0 = time.monotonic()
        B = self.num_slots
        page_table = np.zeros((B, self.pool.pages_per_seq), np.int32)
        pos = np.zeros(B, np.int32)
        tok = np.zeros(B, np.int32)
        uids = np.zeros(B, np.int32)
        for s, q in stepping:
            page_table[s] = self.pool.page_row(q.uid)
            pos[s] = q.length - 1                  # KV row being written
            tok[s] = q.tokens[-1]
            uids[s] = q.uid
        self.pool.k, self.pool.v, nxt, lp = _decode_round_step(
            params, self.cfg, self.pool.k, self.pool.v,
            jnp.asarray(page_table), jnp.asarray(pos), jnp.asarray(tok),
            jnp.asarray(uids), self._base_key, page_size=self.page_size,
            temperature=self.temperature, use_pallas=self.use_pallas,
            mesh=self.mesh)
        nxt, lp = np.asarray(nxt), np.asarray(lp)
        for s, q in stepping:
            self.pool.kv_len[q.uid] = q.length
            self._append_token(q, int(nxt[s]), float(lp[s]))
        self._h_decode.observe(time.monotonic() - t0)
        self._retire(finished, paused, emit)

    def _retire(self, finished, paused, emit) -> None:
        """Free slots of finished/paused sequences (per-sample handoff:
        a finished sequence is emitted immediately, and its slot is
        available to the next waiting prompt on the same loop pass)."""
        for s, q in self.scheduler.active():
            if q.done:
                self.scheduler.release(s)
                self.pool.release(q.uid)
                finished.append(q)
                if emit is not None:
                    emit(q)
            elif q.paused:
                self.scheduler.release(s)          # pages stay parked
                self._parked[q.uid] = q
                paused.append(q)
        self._g_occupancy.set(self.scheduler.occupancy)
        self._g_pages.set(self.pool.pages_in_use)

    # ------------------------------------------------------------------ #
    # maintenance                                                         #
    # ------------------------------------------------------------------ #

    def drop_parked(self, uid: int) -> None:
        """Discard a parked continuation's pages (abandoned rollout)."""
        self._parked.pop(uid, None)
        self.pool.release(uid)
