"""JAX training engines — the training-cluster backend.

``JaxTrainEngine`` implements the actor-update stage verb
(``update_actor``): it accumulates gradients over streamed micro-batches
and applies the AdamW step once a full global batch has passed through
(so streaming micro-consumption is algorithm-identical to whole-batch
training). ``algorithm="grpo"`` uses the GRPO loss over scalar group
advantages; ``algorithm="ppo"`` uses the actor-only PPO loss over
per-token GAE advantages.

``JaxCriticEngine`` implements the PPO value-side stage verbs:
``compute_values`` (the streaming critic-inference task) and
``update_critic`` (the streaming critic-update task), with the same
gradient-accumulation contract as the actor.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engines.adapter import EngineRegistry, RLAdapter
from repro.rl.grpo import GRPOConfig, grpo_loss_fn
from repro.rl.ppo import (PPOConfig, critic_forward, ppo_actor_loss_fn,
                          ppo_critic_loss_fn)
from repro.training.optimizer import OptimizerConfig
from repro.training.train_state import TrainState


def pack_rows(batch: Dict[str, list], seq_len: int) -> dict:
    """Variable-length rows from TransferQueue -> fixed-shape jnp batch.

    Packs whatever per-token columns are present (logprob, ref_logprob,
    returns, values) plus the advantage — per-token (PPO/GAE) or scalar
    per-sample (GRPO) — so one packer serves every train-side stage."""
    n = len(batch["response"])
    S = seq_len

    def pad2(rows, dtype=np.float32):
        a = np.zeros((n, S), dtype)
        for i, r in enumerate(rows):
            r = np.asarray(r)[:S]
            a[i, :len(r)] = r
        return a

    tokens = pad2(batch["response"], np.int32)
    if "response_mask" in batch:
        masks = pad2(batch["response_mask"])
    else:
        masks = np.zeros((n, S), np.float32)
        for i, r in enumerate(batch["response"]):
            masks[i, :min(S, len(np.asarray(r)))] = 1.0
    out = {"tokens": jnp.asarray(tokens),
           "response_mask": jnp.asarray(masks)}
    if "logprob" in batch:
        out["old_logprob"] = jnp.asarray(pad2(batch["logprob"]))
    if "advantage" in batch:
        adv = batch["advantage"]
        if n and np.ndim(np.asarray(adv[0])) >= 1:   # per-token (PPO)
            out["advantage"] = jnp.asarray(pad2(adv))
        else:                                         # scalar (GRPO)
            out["advantage"] = jnp.asarray(np.asarray(adv, np.float32))
    for col, key in (("ref_logprob", "ref_logprob"),
                     ("returns", "returns"), ("values", "old_values")):
        if col in batch:
            out[key] = jnp.asarray(pad2(batch[col]))
    return out


@functools.partial(jax.jit, static_argnames=("cfg", "rl"))
def _grad_microbatch(params, cfg, rl, batch):
    (_, metrics), grads = jax.value_and_grad(grpo_loss_fn, has_aux=True)(
        params, cfg, batch, rl)
    return grads, metrics


@functools.partial(jax.jit, static_argnames=("cfg", "rl"))
def _ppo_actor_grad_microbatch(params, cfg, rl, batch):
    (_, metrics), grads = jax.value_and_grad(
        ppo_actor_loss_fn, has_aux=True)(params, cfg, batch, rl)
    return grads, metrics


@functools.partial(jax.jit, static_argnames=("opt_cfg",))
def _apply(state: TrainState, grads, n_micro, opt_cfg):
    grads = jax.tree.map(lambda g: g / n_micro, grads)
    new_state, gnorm = state.apply_gradients(grads, opt_cfg)
    return new_state, gnorm


class _AccumulatingEngine(RLAdapter):
    """Shared gradient-accumulation consumer: collect micro-batch grads
    until a full global batch streamed through, then step the optimizer."""

    def __init__(self, cfg, init_params, *, opt: Optional[OptimizerConfig],
                 global_batch: int, seq_len: int):
        self.cfg = cfg
        self.opt_cfg = opt or OptimizerConfig(lr=3e-4, warmup_steps=2)
        self.state = TrainState.create(init_params)
        self.global_batch = global_batch
        self.seq_len = seq_len
        self._accum = None
        self._accum_n = 0
        self._accum_metrics: List[dict] = []
        self.version = 0

    @property
    def params(self):
        return self.state.params

    def _grad(self, jb):
        raise NotImplementedError

    def _consume(self, batch: Dict[str, list]) -> dict:
        jb = pack_rows(batch, self.seq_len)
        grads, metrics = self._grad(jb)
        if self._accum is None:
            self._accum = grads
        else:
            self._accum = jax.tree.map(jnp.add, self._accum, grads)
        self._accum_n += len(batch["response"])
        self._accum_metrics.append(
            {k: float(v) for k, v in metrics.items()})

        if self._accum_n >= self.global_batch:
            n_micro = max(1, len(self._accum_metrics))
            self.state, gnorm = _apply(self.state, self._accum,
                                       float(n_micro), self.opt_cfg)
            self.version += 1
            out = {k: float(np.mean([m[k] for m in self._accum_metrics]))
                   for k in self._accum_metrics[0]}
            out["grad_norm"] = float(gnorm)
            if "reward" in batch:
                out["mean_reward"] = float(np.mean(batch["reward"]))
            self._accum, self._accum_n = None, 0
            self._accum_metrics = []
            return out
        return {}

    def get_weights(self):
        return self.state.params

    def load_weights(self, weights) -> None:
        self.state = self.state._replace(params=weights)


@EngineRegistry.register("jax_train")
class JaxTrainEngine(_AccumulatingEngine):
    """Actor-update stage engine (GRPO or PPO-actor loss)."""

    def __init__(self, cfg, init_params, *, rl=None,
                 opt: Optional[OptimizerConfig] = None,
                 global_batch: int = 16, seq_len: int = 32,
                 algorithm: str = "grpo", use_pallas: bool = False):
        super().__init__(cfg, init_params, opt=opt,
                         global_batch=global_batch, seq_len=seq_len)
        self.algorithm = algorithm
        # use_pallas routes the whole actor update through the fused
        # kernels/fused_rl_loss hot path (only consulted when no rl
        # config is passed — an explicit config carries its own flag)
        if algorithm == "ppo":
            self.rl = rl or PPOConfig(use_pallas_logprob=use_pallas)
            self._grad_fn = _ppo_actor_grad_microbatch
        else:
            self.rl = rl or GRPOConfig(use_pallas_logprob=use_pallas)
            self._grad_fn = _grad_microbatch

    def _grad(self, jb):
        return self._grad_fn(self.state.params, self.cfg, self.rl, jb)

    def update(self, batch: Dict[str, list]) -> dict:
        return self._consume(batch)

    def update_actor(self, batch, **kw):
        return self._consume(batch)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _critic_values(critic_params, cfg, tokens):
    return critic_forward(critic_params, cfg, tokens)


@functools.partial(jax.jit, static_argnames=("cfg", "rl"))
def _critic_grad_microbatch(critic_params, cfg, rl, batch):
    (_, metrics), grads = jax.value_and_grad(
        ppo_critic_loss_fn, has_aux=True)(critic_params, cfg, batch, rl)
    return grads, metrics


@EngineRegistry.register("jax_critic")
class JaxCriticEngine(_AccumulatingEngine):
    """PPO value-side stage engine: streaming critic inference
    (``compute_values``) and critic updates (``update_critic``)."""

    def __init__(self, cfg, critic_params, *, rl: Optional[PPOConfig] = None,
                 opt: Optional[OptimizerConfig] = None,
                 global_batch: int = 16, seq_len: int = 32):
        super().__init__(cfg, critic_params, opt=opt,
                         global_batch=global_batch, seq_len=seq_len)
        self.rl = rl or PPOConfig()

    def compute_values(self, batch, **kw):
        """Stage verb: per-token values over each row's full sequence
        (padded to a multiple of 8 for XLA compile reuse)."""
        arrs = [np.asarray(r) for r in batch["response"]]
        S = max(len(a) for a in arrs)
        S = ((S + 7) // 8) * 8
        toks = np.zeros((len(arrs), S), np.int32)
        for i, a in enumerate(arrs):
            toks[i, :len(a)] = a
        vals = np.asarray(_critic_values(self.state.params, self.cfg,
                                         jnp.asarray(toks)))
        return {"updates": {"values":
                            [vals[i, :len(a)].astype(np.float32)
                             for i, a in enumerate(arrs)]}}

    def _grad(self, jb):
        return _critic_grad_microbatch(self.state.params, self.cfg,
                                       self.rl, jb)

    def update_critic(self, batch, **kw):
        return self._consume(batch)

    def update(self, batch):
        return self._consume(batch)
