"""JAX training engine — the training-cluster backend.

Implements the AsyncRLRunner consumer protocol: ``update(batch)``
accumulates GRPO gradients over streamed micro-batches and applies the
AdamW step once a full global batch has passed through (so streaming
micro-consumption is algorithm-identical to whole-batch training).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engines.adapter import EngineRegistry, RLAdapter
from repro.rl.grpo import GRPOConfig, grpo_loss_fn
from repro.training.optimizer import OptimizerConfig
from repro.training.train_state import TrainState


@functools.partial(jax.jit, static_argnames=("cfg", "rl"))
def _grad_microbatch(params, cfg, rl, batch):
    (_, metrics), grads = jax.value_and_grad(grpo_loss_fn, has_aux=True)(
        params, cfg, batch, rl)
    return grads, metrics


@functools.partial(jax.jit, static_argnames=("opt_cfg",))
def _apply(state: TrainState, grads, n_micro, opt_cfg):
    grads = jax.tree.map(lambda g: g / n_micro, grads)
    new_state, gnorm = state.apply_gradients(grads, opt_cfg)
    return new_state, gnorm


@EngineRegistry.register("jax_train")
class JaxTrainEngine(RLAdapter):
    def __init__(self, cfg, init_params, *, rl: Optional[GRPOConfig] = None,
                 opt: Optional[OptimizerConfig] = None,
                 global_batch: int = 16, seq_len: int = 32):
        self.cfg = cfg
        self.rl = rl or GRPOConfig()
        self.opt_cfg = opt or OptimizerConfig(lr=3e-4, warmup_steps=2)
        self.state = TrainState.create(init_params)
        self.global_batch = global_batch
        self.seq_len = seq_len
        self._accum = None
        self._accum_n = 0
        self._accum_metrics: List[dict] = []
        self.version = 0

    # AsyncRLRunner protocol --------------------------------------------------
    @property
    def params(self):
        return self.state.params

    def _pack(self, batch: Dict[str, list]) -> dict:
        """Rows from TransferQueue -> fixed-shape jnp batch."""
        n = len(batch["response"])
        S = self.seq_len
        tokens = np.zeros((n, S), np.int32)
        masks = np.zeros((n, S), np.float32)
        old_lp = np.zeros((n, S), np.float32)
        adv = np.asarray(batch["advantage"], np.float32)
        for i in range(n):
            t = np.asarray(batch["response"][i])[:S]
            tokens[i, :len(t)] = t
            m = np.asarray(batch["response_mask"][i])[:S] \
                if "response_mask" in batch else np.ones(len(t))
            masks[i, :len(m)] = m
            lp = np.asarray(batch["logprob"][i])[:S]
            old_lp[i, :len(lp)] = lp
        out = {"tokens": jnp.asarray(tokens),
               "response_mask": jnp.asarray(masks),
               "old_logprob": jnp.asarray(old_lp),
               "advantage": jnp.asarray(adv)}
        if "ref_logprob" in batch:
            ref = np.zeros((n, S), np.float32)
            for i in range(n):
                rl = np.asarray(batch["ref_logprob"][i])[:S]
                ref[i, :len(rl)] = rl
            out["ref_logprob"] = jnp.asarray(ref)
        return out

    def update(self, batch: Dict[str, list]) -> dict:
        jb = self._pack(batch)
        grads, metrics = _grad_microbatch(self.state.params, self.cfg,
                                          self.rl, jb)
        if self._accum is None:
            self._accum = grads
        else:
            self._accum = jax.tree.map(jnp.add, self._accum, grads)
        self._accum_n += len(batch["advantage"])
        self._accum_metrics.append(
            {k: float(v) for k, v in metrics.items()})

        if self._accum_n >= self.global_batch:
            n_micro = max(1, len(self._accum_metrics))
            self.state, gnorm = _apply(self.state, self._accum,
                                       float(n_micro), self.opt_cfg)
            self.version += 1
            out = {k: float(np.mean([m[k] for m in self._accum_metrics]))
                   for k in self._accum_metrics[0]}
            out.update(grad_norm=float(gnorm),
                       mean_reward=float(np.mean(batch["reward"])))
            self._accum, self._accum_n = None, 0
            self._accum_metrics = []
            return out
        return {}

    def update_actor(self, batch, **kw):
        return self.update(batch)

    def get_weights(self):
        return self.state.params

    def load_weights(self, weights) -> None:
        self.state = self.state._replace(params=weights)
