from repro.engines.adapter import EngineRegistry, RLAdapter
from repro.engines.rollout_engine import JaxRolloutEngine
from repro.engines.train_engine import JaxTrainEngine

__all__ = ["RLAdapter", "EngineRegistry", "JaxRolloutEngine",
           "JaxTrainEngine"]
