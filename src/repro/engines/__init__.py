from repro.engines.adapter import EngineRegistry, RLAdapter
from repro.engines.rollout_engine import JaxRolloutEngine
from repro.engines.train_engine import (JaxCriticEngine, JaxTrainEngine,
                                        pack_rows)

__all__ = ["RLAdapter", "EngineRegistry", "JaxRolloutEngine",
           "JaxTrainEngine", "JaxCriticEngine", "pack_rows"]
