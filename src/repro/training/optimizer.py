"""AdamW + LR schedules (cosine, and WSD for MiniCPM) — pure JAX, no optax.

State is a pytree mirroring params: {"m": ..., "v": ..., "count": scalar}.
Supports global-norm clipping and decoupled weight decay.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 1e-5
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    schedule: str = "constant"  # constant | cosine | wsd
    warmup_steps: int = 10
    total_steps: int = 1000
    stable_frac: float = 0.8    # WSD: fraction of steps at peak LR
    min_lr_frac: float = 0.1


def make_schedule(cfg: OptimizerConfig) -> Callable:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
        if cfg.schedule == "constant":
            frac = 1.0
        elif cfg.schedule == "cosine":
            t = jnp.clip((step - cfg.warmup_steps)
                         / max(1, cfg.total_steps - cfg.warmup_steps), 0, 1)
            frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
                1 + jnp.cos(jnp.pi * t))
        elif cfg.schedule == "wsd":
            # warmup -> stable plateau -> 1-sqrt decay (MiniCPM §WSD)
            decay_start = cfg.stable_frac * cfg.total_steps
            t = jnp.clip((step - decay_start)
                         / max(1.0, cfg.total_steps - decay_start), 0, 1)
            frac = jnp.where(step < decay_start, 1.0,
                             cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                             * (1 - jnp.sqrt(t)))
        else:
            raise ValueError(cfg.schedule)
        return cfg.lr * warm * frac
    return sched


def init_opt_state(params):
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def adamw_update(params, grads, state, cfg: OptimizerConfig):
    """Returns (new_params, new_state, grad_norm)."""
    sched = make_schedule(cfg)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state["count"] + 1
    b1, b2 = cfg.betas
    lr = sched(state["count"])

    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g),
                     state["v"], grads)
    c = count.astype(jnp.float32)
    mh_scale = 1.0 / (1 - b1 ** c)
    vh_scale = 1.0 / (1 - b2 ** c)

    def upd(p, m_, v_):
        step = (m_ * mh_scale) / (jnp.sqrt(v_ * vh_scale) + cfg.eps)
        return (p - lr * (step + cfg.weight_decay * p)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "count": count}, gnorm
