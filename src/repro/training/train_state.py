"""TrainState — params + optimizer state + step counter pytree."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.training.optimizer import (OptimizerConfig, adamw_update,
                                      init_opt_state)


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray

    @classmethod
    def create(cls, params):
        return cls(params, init_opt_state(params), jnp.zeros((), jnp.int32))

    def apply_gradients(self, grads, opt_cfg: OptimizerConfig):
        new_params, new_opt, gnorm = adamw_update(
            self.params, grads, self.opt_state, opt_cfg)
        return self._replace(params=new_params, opt_state=new_opt,
                             step=self.step + 1), gnorm
