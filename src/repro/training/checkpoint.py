"""Checkpointing — save/restore arbitrary pytrees (params, optimizer
state) to an .npz + JSON treedef pair. Works for sharded arrays by
gathering to host (fine for the CPU container; on a real cluster this is
the per-host shard writer plug point).

Saves are crash-atomic: both files are written into a temp directory,
fsynced, and the directory is renamed into place in one step — a process
killed mid-save can never leave a half-written checkpoint that
:func:`restore_checkpoint` would load. When overwriting an existing
checkpoint the old directory is moved aside first, so every observable
state is either the complete old checkpoint, the complete new one, or
(for the instant between the two renames) no checkpoint at all — never
a torn mix of the two.
"""
from __future__ import annotations

import json
import os
import shutil
import uuid
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def fsync_path(path: str) -> None:
    """fsync a file or directory so the rename that follows is durable."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    except OSError:
        pass        # some filesystems refuse dir fsync; rename still atomic
    finally:
        os.close(fd)


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    path = os.path.normpath(path)
    parent = os.path.dirname(path) or "."
    os.makedirs(parent, exist_ok=True)
    keys, vals, _ = _flatten_with_paths(tree)
    arrays = {f"a{i}": np.asarray(v) for i, v in enumerate(vals)}
    nonce = uuid.uuid4().hex[:8]
    tmp = f"{path}.tmp-{os.getpid()}-{nonce}"
    os.makedirs(tmp)
    try:
        npz = os.path.join(tmp, "arrays.npz")
        np.savez(npz, **arrays)
        fsync_path(npz)
        meta_path = os.path.join(tmp, "meta.json")
        with open(meta_path, "w") as f:
            json.dump({"step": int(step), "keys": keys}, f)
            f.flush()
            os.fsync(f.fileno())
        fsync_path(tmp)
        if os.path.isdir(path):
            old = f"{path}.old-{os.getpid()}-{nonce}"
            os.rename(path, old)
            os.rename(tmp, path)
            shutil.rmtree(old, ignore_errors=True)
        else:
            os.rename(tmp, path)
        fsync_path(parent)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def restore_checkpoint(path: str, like: Any):
    """Restore into the structure of ``like``. Returns (tree, step)."""
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    keys_saved = meta["keys"]
    keys_like, vals_like, treedef = _flatten_with_paths(like)
    if keys_saved != keys_like:
        step = meta.get("step")
        extra = sorted(set(keys_saved) - set(keys_like))
        missing = sorted(set(keys_like) - set(keys_saved))
        if not extra and not missing:
            pos, a, b = next(
                (i, a, b) for i, (a, b)
                in enumerate(zip(keys_saved, keys_like)) if a != b)
            detail = (f"same keys, different treedef order — first "
                      f"divergence at leaf {pos}: checkpoint has {a!r}, "
                      f"target expects {b!r}")
        else:
            detail = (f"only in checkpoint: {extra}; "
                      f"only in target: {missing}")
        raise ValueError(
            f"checkpoint structure mismatch (checkpoint saved at "
            f"step {step}): {detail}")
    vals = [jax.numpy.asarray(data[f"a{i}"]).astype(v.dtype)
            for i, v in enumerate(vals_like)]
    return jax.tree_util.tree_unflatten(treedef, vals), meta["step"]
