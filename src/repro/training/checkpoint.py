"""Checkpointing — save/restore arbitrary pytrees (params, optimizer
state) to an .npz + JSON treedef pair. Works for sharded arrays by
gathering to host (fine for the CPU container; on a real cluster this is
the per-host shard writer plug point)."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    os.makedirs(path, exist_ok=True)
    keys, vals, _ = _flatten_with_paths(tree)
    arrays = {f"a{i}": np.asarray(v) for i, v in enumerate(vals)}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    meta = {"step": step, "keys": keys}
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)


def restore_checkpoint(path: str, like: Any):
    """Restore into the structure of ``like``. Returns (tree, step)."""
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    keys_saved = meta["keys"]
    keys_like, vals_like, treedef = _flatten_with_paths(like)
    if keys_saved != keys_like:
        raise ValueError("checkpoint structure mismatch: "
                         f"{set(keys_saved) ^ set(keys_like)}")
    vals = [jax.numpy.asarray(data[f"a{i}"]).astype(v.dtype)
            for i, v in enumerate(vals_like)]
    return jax.tree_util.tree_unflatten(treedef, vals), meta["step"]
