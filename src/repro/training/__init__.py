from repro.training.checkpoint import restore_checkpoint, save_checkpoint
from repro.training.optimizer import (OptimizerConfig, adamw_update,
                                      clip_by_global_norm, init_opt_state,
                                      make_schedule)
from repro.training.train_state import TrainState

__all__ = ["OptimizerConfig", "adamw_update", "init_opt_state",
           "make_schedule", "clip_by_global_norm", "TrainState",
           "save_checkpoint", "restore_checkpoint"]
