"""Multi-pod dry-run: prove the distribution config lowers + compiles for
every (architecture x input shape x mesh) without hardware.

MUST set the fake-device flag before any other import (jax locks device
count on first init).

Usage:
  python -m repro.launch.dryrun --arch qwen2_5_7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out-dir results/dryrun
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_FLAGS",
                           "--xla_force_host_platform_device_count=512"))

# ruff: noqa: E402
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import INPUT_SHAPES
from repro.distributed.sharding import (batch_pspecs, cache_pspecs, dp_axes,
                                        state_pspecs, to_named, tree_pspecs)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, params_struct, state_struct
from repro.launch.steps import make_prefill_step, make_serve_step, \
    make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P

# hardware constants (TPU v5e-class target; see EXPERIMENTS.md §Roofline)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link per chip

COLLECTIVE_RE = re.compile(
    r"^\s*(?:%|\S+ = )?"
    r"(?P<shape>\(?[a-z0-9]+\[[0-9,]*\][^ ]*\)?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)", re.M)

SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
               "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes_from_hlo(hlo: str, loop_mult: int) -> dict:
    """Sum output bytes of collective ops. Ops inside while-loop bodies
    (the layer scan) are multiplied by ``loop_mult`` — a documented
    approximation (the only while loops in these steps are layer stacks).
    """
    per_op = {}
    total = 0.0
    # split into computations; while bodies are named *body*
    comps = re.split(r"\n(?=[%\w].*\{)", hlo)
    for comp in comps:
        header = comp.split("\n", 1)[0]
        in_loop = ("body" in header) or ("while" in header)
        mult = loop_mult if in_loop else 1
        for m in COLLECTIVE_RE.finditer(comp):
            b = _shape_bytes(m.group("shape")) * mult
            per_op[m.group("op")] = per_op.get(m.group("op"), 0) + b
            total += b
    per_op["total"] = total
    return per_op


def build_lowered(arch: str, shape_name: str, mesh, *, overrides=None,
                  kv_seq_shard=False):
    import dataclasses as _dc
    cfg = get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    kind, specs = input_specs(cfg, shape_name)

    if kind == "train":
        step = make_train_step(cfg)
        state = state_struct(cfg)
        st_sh = to_named(state_pspecs(state, cfg, mesh), mesh)
        b_sh = to_named(batch_pspecs(specs["batch"], cfg, mesh), mesh)
        fn = jax.jit(step, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, None))
        with mesh:
            return fn.lower(state, specs["batch"]), cfg

    params = params_struct(cfg)
    p_sh = to_named(tree_pspecs(params, cfg, mesh), mesh)

    if kind == "prefill":
        step = make_prefill_step(cfg)
        b_sh = to_named(batch_pspecs(specs["batch"], cfg, mesh), mesh)
        fn = jax.jit(step, in_shardings=(p_sh, b_sh))
        with mesh:
            return fn.lower(params, specs["batch"]), cfg

    # decode
    step = make_serve_step(cfg, ring=specs["ring"])
    B = specs["token"].shape[0]
    c_sh = to_named(cache_pspecs(specs["cache"], cfg, mesh, batch=B,
                                 kv_seq_shard=kv_seq_shard), mesh)
    dp = dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in
                        (dp if isinstance(dp, tuple) else (dp,))]))
    tok_spec = P(dp) if B % n_dp == 0 and B > 1 else P()
    t_sh = NamedSharding(mesh, tok_spec)
    fn = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh, t_sh),
                 out_shardings=(None, c_sh))
    with mesh:
        return fn.lower(params, specs["cache"], specs["token"],
                        specs["pos"]), cfg


def model_flops(cfg, shape_name: str) -> float:
    """6·N_active·D (training) / 2·N_active·D (per-token inference) — the
    'useful' MFU-accounting FLOPs."""
    shp = INPUT_SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shp.kind == "train":
        return 6.0 * n_active * shp.global_batch * shp.seq_len
    if shp.kind == "prefill":
        return 2.0 * n_active * shp.global_batch * shp.seq_len
    return 2.0 * n_active * shp.global_batch  # decode: one token per seq


def run_one(arch: str, shape_name: str, mesh_kind: str, *, overrides=None,
            mesh_shape=None, kv_seq_shard=False) -> dict:
    from repro.core.planner.cost_model import HW, roofline_terms

    if mesh_shape:  # hillclimb meshes, e.g. "32x8"
        dims = [int(x) for x in mesh_shape.split("x")]
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = jax.make_mesh(tuple(dims), axes)
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "pod"))
    n_chips = mesh.devices.size
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.supports_long_decode:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped",
                "reason": "enc-dec (448 decoder positions); see DESIGN.md"}

    t0 = time.time()
    lowered, cfg = build_lowered(arch, shape_name, mesh,
                                 overrides=overrides,
                                 kv_seq_shard=kv_seq_shard)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    # NOTE: on the CPU backend cost_analysis() counts while-loop (layer
    # scan) bodies ONCE, so these raw values undercount; the roofline uses
    # the analytic cost model (planner §4.3) — see EXPERIMENTS.md §Roofline.
    flops_raw = float(cost.get("flops", 0.0))
    bytes_raw = float(cost.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    loop_mult = max(1, cfg.num_layers // (len(cfg.rglru_block_pattern)
                    if cfg.arch_type == "hybrid" else 1))
    coll_hlo = collective_bytes_from_hlo(hlo, loop_mult)

    mesh_shape_d = {a: int(mesh.shape[a]) for a in mesh.axis_names}
    rt = roofline_terms(cfg, shape_name, mesh_shape_d,
                        kv_seq_shard=kv_seq_shard)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "n_chips": int(n_chips),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        # compiled-artifact evidence
        "hlo_flops_raw": flops_raw, "hlo_bytes_raw": bytes_raw,
        "hlo_collective_bytes": coll_hlo,
        "hlo_collective_ops": {k: v for k, v in coll_hlo.items()
                               if k != "total"},
        # analytic roofline (planner cost model)
        "flops": rt["flops"],
        "hbm_bytes_per_chip": rt["hbm_bytes_per_chip"],
        "collective_bytes_per_chip": rt["collective_bytes_per_chip"],
        "t_compute": rt["t_compute"], "t_memory": rt["t_memory"],
        "t_collective": rt["t_collective"],
        "bottleneck": rt["bottleneck"],
        "model_flops": model_flops(cfg, shape_name),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    rec["useful_flops_ratio"] = rec["model_flops"] / max(rt["flops"], 1.0)
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                rec[attr] = int(v)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "pod"])
    ap.add_argument("--mesh-shape", default=None,
                    help="hillclimb mesh, e.g. 32x8 (data x model)")
    ap.add_argument("--set", action="append", default=[],
                    help="config override, e.g. --set ssm_chunk=256")
    ap.add_argument("--kv-seq-shard", action="store_true",
                    help="shard decode KV cache sequence dim over 'model'")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = int(v) if v.lstrip("-").isdigit() else v
    rec_args = dict(arch=args.arch, shape_name=args.shape,
                    mesh_kind=args.mesh, overrides=overrides or None,
                    mesh_shape=args.mesh_shape,
                    kv_seq_shard=args.kv_seq_shard)
    try:
        rec = run_one(**rec_args)
    except Exception as e:  # record the failure — these are bugs to fix
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    out = json.dumps(rec, indent=1)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(out)
    print(out)
    return 0 if rec.get("status") in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
