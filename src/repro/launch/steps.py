"""The three production step functions every architecture lowers:

  train_step   — GRPO actor update (fwd + clipped policy loss + bwd + AdamW)
  prefill_step — rollout prefill: full-sequence forward building the KV cache
  serve_step   — one-token decode against a seq_len cache

These are what the dry-run lowers for every (arch x input-shape x mesh).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import decode_step, forward
from repro.rl.grpo import GRPOConfig, grpo_loss_fn
from repro.training.optimizer import OptimizerConfig
from repro.training.train_state import TrainState


def make_train_step(cfg, rl: GRPOConfig = None,
                    opt_cfg: OptimizerConfig = None):
    rl = rl or GRPOConfig()
    opt_cfg = opt_cfg or OptimizerConfig()

    def train_step(state: TrainState, batch):
        (_, metrics), grads = jax.value_and_grad(
            grpo_loss_fn, has_aux=True)(state.params, cfg, batch, rl)
        new_state, gnorm = state.apply_gradients(grads, opt_cfg)
        metrics["grad_norm"] = gnorm
        return new_state, metrics

    return train_step


def make_prefill_step(cfg):
    """Returns (last-token logits, cache-or-None)."""
    want_cache = cfg.arch_type not in ("ssm",)

    def prefill_step(params, batch):
        out = forward(params, cfg, batch, return_cache=want_cache)
        if want_cache:
            logits, aux, cache = out
        else:
            logits, aux = out
            cache = None
        return logits[:, -1, :], cache

    return prefill_step


def make_serve_step(cfg, *, ring: bool = False):
    def serve_step(params, cache, token, pos):
        return decode_step(params, cfg, cache, token, pos, ring=ring)

    return serve_step
