"""Serving launcher: batched-request generation with the rollout engine
(the inference-cluster side of AsyncFlow, standalone).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_5_7b \
      --requests 8 --max-new-tokens 16 --engine continuous

``--engine continuous`` serves through the same
``engines/continuous_batching`` subsystem the RL rollout stage uses
(slot scheduler + paged KV cache), so inference traffic and training
rollouts share one engine; ``fixed`` keeps the padded-batch decode loop.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", choices=("fixed", "continuous"),
                    default="fixed")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (continuous engine)")
    args = ap.parse_args(argv)

    import jax

    from repro.configs import get_config
    from repro.data import PromptDataset
    from repro.data.tokenizer import ByteTokenizer
    from repro.models import init_params

    tok = ByteTokenizer()
    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              vocab_size=tok.vocab_size)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    ds = PromptDataset(seed=args.seed)
    prompts = ds.prompts_for_step(0, args.requests)

    t0 = time.time()
    n_tokens = 0
    outputs = []
    if args.engine == "continuous":
        from repro.engines.continuous_batching import \
            ContinuousBatchingEngine
        max_len = max(len(p["tokens"]) for p in prompts) \
            + args.max_new_tokens
        eng = ContinuousBatchingEngine(
            cfg, num_slots=args.slots, max_len=max_len,
            max_new_tokens=args.max_new_tokens,
            temperature=args.temperature, seed=args.seed)
        seqs = [eng.make_sequence(p["tokens"], meta={"prompt": p})
                for p in prompts]
        done, _ = eng.generate(params, seqs)
        done.sort(key=lambda q: q.uid)
        for q in done:
            ids = q.tokens[q.prompt_len:]
            outputs.append({"prompt": q.meta["prompt"]["text"],
                            "response": tok.decode(ids)})
            n_tokens += len(ids)
    else:
        from repro.rl.sampling import generate
        for i in range(0, len(prompts), args.batch_size):
            chunk = prompts[i:i + args.batch_size]
            rows = generate(params, cfg, [p["tokens"] for p in chunk],
                            args.seed + i,
                            max_new_tokens=args.max_new_tokens,
                            temperature=args.temperature)
            for p, r in zip(chunk, rows):
                outputs.append({"prompt": p["text"],
                                "response": tok.decode(r["response_ids"])})
                n_tokens += len(r["response_ids"])
    wall = time.time() - t0
    print(json.dumps({"arch": args.arch, "engine": args.engine,
                      "requests": len(prompts),
                      "wall_s": round(wall, 2),
                      "tokens_per_s": round(n_tokens / wall, 1),
                      "samples": outputs[:4]}, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
