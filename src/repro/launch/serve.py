"""Serving launcher: batched-request generation with the rollout engine
(the inference-cluster side of AsyncFlow, standalone).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_5_7b \
      --requests 8 --max-new-tokens 16 --engine continuous

``--engine continuous`` serves through the same
``engines/continuous_batching`` subsystem the RL rollout stage uses
(slot scheduler + paged KV cache), so inference traffic and training
rollouts share one engine; ``fixed`` keeps the padded-batch decode loop.

``--replicas N`` serves through a supervised generator fleet: N replica
threads behind a :class:`ReplicaSupervisor` service registry. With
``--crash-p`` > 0 a deterministic :class:`FaultInjector` kills replicas
mid-serve; crashed replicas requeue their in-flight request to the front
of the work queue and are respawned, so every request completes exactly
once:

  PYTHONPATH=src python -m repro.launch.serve --engine continuous \
      --replicas 3 --crash-p 0.1 --fault-seed 7
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
import json
import sys
import threading
import time


def _serve_fleet(args, cfg, params, prompts, tok):
    """Supervised replica fleet: a shared work queue drained by N replica
    threads; crashes requeue the in-flight request and respawn."""
    import collections

    from repro.core.supervision import (FaultConfig, FaultInjector,
                                        ReplicaCrash, ReplicaSupervisor)
    from repro.engines.continuous_batching import ContinuousBatchingEngine

    work = collections.deque(enumerate(prompts))
    wlock = threading.Lock()
    outputs: dict = {}
    stop = threading.Event()
    inj = FaultInjector(FaultConfig(crash_p=args.crash_p,
                                    seed=args.fault_seed,
                                    stages=("serve",)))
    max_len = max(len(p["tokens"]) for p in prompts) + args.max_new_tokens
    sup = ReplicaSupervisor(lambda dead: _spawn(),
                            heartbeat_timeout_s=60.0,
                            max_restarts=0, stage="serve")
    rid_seq = itertools.count()

    def _replica(handle):
        eng = ContinuousBatchingEngine(
            cfg, num_slots=args.slots, max_len=max_len,
            max_new_tokens=args.max_new_tokens,
            temperature=args.temperature, seed=args.seed)
        while not stop.is_set():
            handle.beat()
            with wlock:
                if not work:
                    sup.retire(handle.rid)
                    return
                item = work.popleft()
            try:
                inj.check("serve", handle.rid)
                i, p = item
                q = eng.make_sequence(p["tokens"], meta={"prompt": p})
                done, _ = eng.generate(params, [q])
                ids = done[0].tokens[done[0].prompt_len:]
                with wlock:
                    outputs[i] = {"prompt": p["text"],
                                  "response": tok.decode(ids)}
            except ReplicaCrash as e:
                with wlock:
                    work.appendleft(item)    # in-flight request requeues
                sup.report_death(handle.rid, repr(e))
                return
        sup.retire(handle.rid)

    def _spawn() -> bool:
        rid = next(rid_seq)
        h = sup.register(rid, None, stage="serve")
        t = threading.Thread(target=_replica, args=(h,), daemon=True)
        h.thread = t
        t.start()
        return True

    for _ in range(args.replicas):
        _spawn()
    while len(outputs) < len(prompts):
        sup.poll()
        time.sleep(0.01)
    stop.set()
    return [outputs[i] for i in range(len(prompts))], sup.restarts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", choices=("fixed", "continuous"),
                    default="fixed")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (continuous engine)")
    ap.add_argument("--replicas", type=int, default=1,
                    help=">1: supervised generator fleet (continuous)")
    ap.add_argument("--crash-p", type=float, default=0.0,
                    help="deterministic crash probability per request")
    ap.add_argument("--fault-seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax

    from repro.configs import get_config
    from repro.data import PromptDataset
    from repro.data.tokenizer import ByteTokenizer
    from repro.models import init_params

    tok = ByteTokenizer()
    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              vocab_size=tok.vocab_size)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    ds = PromptDataset(seed=args.seed)
    prompts = ds.prompts_for_step(0, args.requests)

    t0 = time.time()
    n_tokens = 0
    outputs = []
    restarts = 0
    if args.replicas > 1:
        outputs, restarts = _serve_fleet(args, cfg, params, prompts, tok)
        n_tokens = sum(len(tok.encode(o["response"])) for o in outputs)
    elif args.engine == "continuous":
        from repro.engines.continuous_batching import \
            ContinuousBatchingEngine
        max_len = max(len(p["tokens"]) for p in prompts) \
            + args.max_new_tokens
        eng = ContinuousBatchingEngine(
            cfg, num_slots=args.slots, max_len=max_len,
            max_new_tokens=args.max_new_tokens,
            temperature=args.temperature, seed=args.seed)
        seqs = [eng.make_sequence(p["tokens"], meta={"prompt": p})
                for p in prompts]
        done, _ = eng.generate(params, seqs)
        done.sort(key=lambda q: q.uid)
        for q in done:
            ids = q.tokens[q.prompt_len:]
            outputs.append({"prompt": q.meta["prompt"]["text"],
                            "response": tok.decode(ids)})
            n_tokens += len(ids)
    else:
        from repro.rl.sampling import generate
        for i in range(0, len(prompts), args.batch_size):
            chunk = prompts[i:i + args.batch_size]
            rows = generate(params, cfg, [p["tokens"] for p in chunk],
                            args.seed + i,
                            max_new_tokens=args.max_new_tokens,
                            temperature=args.temperature)
            for p, r in zip(chunk, rows):
                outputs.append({"prompt": p["text"],
                                "response": tok.decode(r["response_ids"])})
                n_tokens += len(r["response_ids"])
    wall = time.time() - t0
    print(json.dumps({"arch": args.arch, "engine": args.engine,
                      "requests": len(prompts),
                      "replicas": args.replicas,
                      "replica_restarts": restarts,
                      "wall_s": round(wall, 2),
                      "tokens_per_s": round(n_tokens / wall, 1),
                      "samples": outputs[:4]}, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
