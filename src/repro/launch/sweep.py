"""Dry-run sweep driver: every (arch x shape x mesh) as a subprocess
(isolated device state + memory), resumable via the output directory.

  PYTHONPATH=src python -m repro.launch.sweep --out-dir results/dryrun
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = [
    "recurrentgemma_9b", "stablelm_12b", "minicpm3_4b", "grok_1_314b",
    "whisper_tiny", "minicpm_2b", "qwen1_5_32b", "falcon_mamba_7b",
    "deepseek_v2_236b", "internvl2_26b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
MESHES = ["single", "pod"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--meshes", default="single,pod")
    ap.add_argument("--archs", default=",".join(ARCHS))
    ap.add_argument("--shapes", default=",".join(SHAPES))
    ap.add_argument("--timeout", type=float, default=1800.0)
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    combos = [(a, s, m)
              for m in args.meshes.split(",")
              for a in args.archs.split(",")
              for s in args.shapes.split(",")]
    t0 = time.time()
    n_ok = n_fail = n_skip = 0
    for i, (arch, shape, mesh) in enumerate(combos):
        out = os.path.join(args.out_dir, f"{arch}__{shape}__{mesh}.json")
        if os.path.exists(out):
            try:
                st = json.load(open(out)).get("status")
                if st in ("ok", "skipped"):
                    print(f"[{i+1}/{len(combos)}] cached {arch} {shape} "
                          f"{mesh}: {st}", flush=True)
                    n_ok += st == "ok"
                    n_skip += st == "skipped"
                    continue
            except Exception:
                pass
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh, "--out", out]
        t1 = time.time()
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            rec = json.load(open(out)) if os.path.exists(out) else {}
            st = rec.get("status", f"rc={r.returncode}")
            if not os.path.exists(out):
                with open(out, "w") as f:
                    json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                               "status": "error",
                               "error": (r.stderr or "")[-2000:]}, f)
                st = "error"
        except subprocess.TimeoutExpired:
            with open(out, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "status": "error", "error": "timeout"}, f)
            st = "timeout"
        dt = time.time() - t1
        n_ok += st == "ok"
        n_skip += st == "skipped"
        n_fail += st not in ("ok", "skipped")
        print(f"[{i+1}/{len(combos)}] {arch} {shape} {mesh}: {st} "
              f"({dt:.0f}s, total {time.time()-t0:.0f}s)", flush=True)
    print(f"DONE ok={n_ok} skipped={n_skip} failed={n_fail}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
