"""End-to-end training launcher.

CPU container: runs the full AsyncFlow GRPO post-training workflow on a
reduced architecture (real rollout + real updates through TransferQueue).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2_5_7b \
      --mode async --steps 20
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_7b")
    ap.add_argument("--mode", default="async",
                    choices=["baseline", "streaming", "async"])
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--prompts-per-step", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=4)
    ap.add_argument("--rollout-workers", type=int, default=2)
    ap.add_argument("--max-new-tokens", type=int, default=6)
    ap.add_argument("--staleness", type=int, default=1)
    ap.add_argument("--staggered", action="store_true",
                    help="sub-step async weight updates (Fig. 8d)")
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="partial rollout chunk size (0 = off)")
    ap.add_argument("--policy", default="fifo",
                    choices=["fifo", "token_balance"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default="",
                    help="durable run-snapshot directory (enables warm "
                         "trainer recovery and --resume)")
    ap.add_argument("--checkpoint-interval", type=int, default=1,
                    help="snapshot every N steps (0 = start/end only)")
    ap.add_argument("--resume", default=None,
                    help='"auto" or a snapshot path: cold-resume a '
                         "killed run from its newest intact snapshot")
    ap.add_argument("--gantt", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from repro.api import Trainer, TrainerConfig

    tcfg = TrainerConfig(
        arch=args.arch, mode=args.mode, num_steps=args.steps,
        prompts_per_step=args.prompts_per_step, group_size=args.group_size,
        rollout_workers=args.rollout_workers,
        max_new_tokens=args.max_new_tokens, staleness=args.staleness,
        staggered=args.staggered, policy=args.policy, lr=args.lr,
        seed=args.seed, chunk_tokens=args.chunk_tokens,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_interval_steps=args.checkpoint_interval)
    result = Trainer(tcfg).fit(resume=args.resume)

    summary = {
        "mode": args.mode, "arch": args.arch,
        "wall_time_s": round(result.wall_time_s, 3),
        "throughput_samples_per_s": round(result.throughput, 2),
        "max_staleness": max(result.staleness_seen),
        "mean_reward_last": result.metrics[-1].get("mean_reward")
        if result.metrics else None,
        "bubble_fraction": {k: round(v, 3)
                            for k, v in result.bubble_fraction.items()},
    }
    print(json.dumps(summary, indent=1))
    if args.gantt:
        print(result.log.render_gantt())
    if args.out:
        with open(args.out, "w") as f:
            json.dump({**summary, "metrics": result.metrics}, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
