"""input_specs — ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, zero allocation).

One entry point per step kind; shapes come from the assigned INPUT_SHAPES
table. Audio/VLM modality frontends are stubs: ``frames`` /
``vision_embeds`` arrive as precomputed embeddings of the right shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, ModelConfig
from repro.models import init_cache, init_params
from repro.models.layers import dtype_of
from repro.models.model import decode_window
from repro.training.train_state import TrainState


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def params_struct(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(init_params, cfg=cfg), jax.random.PRNGKey(0))


def state_struct(cfg: ModelConfig):
    p = params_struct(cfg)
    return jax.eval_shape(TrainState.create, p)


def train_specs(cfg: ModelConfig, shape_name: str = "train_4k"):
    shp = INPUT_SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    batch = {
        "tokens": _sds((B, S), jnp.int32),
        "response_mask": _sds((B, S), jnp.float32),
        "old_logprob": _sds((B, S), jnp.float32),
        "advantage": _sds((B,), jnp.float32),
    }
    cd = dtype_of(cfg.compute_dtype)
    if cfg.arch_type == "audio":
        batch["frames"] = _sds((B, cfg.encoder_frames, cfg.d_model), cd)
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = _sds((B, cfg.vision_tokens, cfg.d_model), cd)
    return batch


def prefill_specs(cfg: ModelConfig, shape_name: str = "prefill_32k"):
    shp = INPUT_SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    batch = {"tokens": _sds((B, S), jnp.int32)}
    cd = dtype_of(cfg.compute_dtype)
    if cfg.arch_type == "audio":
        batch["frames"] = _sds((B, cfg.encoder_frames, cfg.d_model), cd)
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = _sds((B, cfg.vision_tokens, cfg.d_model), cd)
    return batch


def decode_specs(cfg: ModelConfig, shape_name: str):
    """(cache, token, pos) structs; cache length follows decode_window
    (sliding-window ring for dense long_500k)."""
    shp = INPUT_SHAPES[shape_name]
    B = shp.global_batch
    length, ring = decode_window(cfg, shape_name)
    cache = jax.eval_shape(
        functools.partial(init_cache, cfg, B, length))
    token = _sds((B,), jnp.int32)
    pos = _sds((B,), jnp.int32)
    return cache, token, pos, ring


def input_specs(cfg: ModelConfig, shape_name: str):
    """Unified: returns (kind, specs_dict)."""
    kind = INPUT_SHAPES[shape_name].kind
    if kind == "train":
        return kind, {"batch": train_specs(cfg, shape_name)}
    if kind == "prefill":
        return kind, {"batch": prefill_specs(cfg, shape_name)}
    cache, token, pos, ring = decode_specs(cfg, shape_name)
    return kind, {"cache": cache, "token": token, "pos": pos, "ring": ring}
