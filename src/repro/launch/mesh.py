"""Production mesh builders.

A function (not module-level constant) so importing never touches jax
device state. Single pod: 16x16 = 256 chips (data x model). Multi-pod:
2 x 16 x 16 = 512 chips with a leading pure-DP "pod" axis.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *, pod: int = 0):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
