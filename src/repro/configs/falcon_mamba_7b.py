"""Falcon-Mamba-7B — attention-free mamba-1 SSM. [arXiv:2410.05355]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    arch_type="ssm",
    citation="arXiv:2410.05355 (Falcon Mamba)",
    num_layers=64,
    d_model=4096,
    num_heads=1,
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=65_024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    tie_embeddings=True,
)
