"""Qwen2.5-7B — the paper's primary evaluation model. [arXiv:2412.15115]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-7b",
    arch_type="dense",
    citation="arXiv:2412.15115 (Qwen2.5); AsyncFlow §6.1",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152_064,
    qkv_bias=True,
)
