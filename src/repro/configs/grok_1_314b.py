"""Grok-1 314B — MoE, 8 experts top-2, GQA kv=8. [hf:xai-org/grok-1]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    arch_type="moe",
    citation="hf:xai-org/grok-1",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    moe_d_ff=32768,
    vocab_size=131_072,
    num_experts=8,
    top_k=2,
    activation="gelu",
)
