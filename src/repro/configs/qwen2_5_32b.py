"""Qwen2.5-32B — the paper's large evaluation model. [arXiv:2412.15115]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    arch_type="dense",
    citation="arXiv:2412.15115 (Qwen2.5); AsyncFlow §6.1",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152_064,
    qkv_bias=True,
)
