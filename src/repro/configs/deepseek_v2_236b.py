"""DeepSeek-V2 236B — MoE with MLA. 2 shared + 160 routed experts top-6,
kv_lora_rank=512, fine-grained experts d_ff=1536. [arXiv:2405.04434]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    citation="arXiv:2405.04434 (DeepSeek-V2)",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=12288,  # dense layers (first_dense_layers)
    moe_d_ff=1536,
    vocab_size=102_400,
    attention="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    num_experts=160,
    num_shared_experts=2,
    top_k=6,
    first_dense_layers=1,
)
