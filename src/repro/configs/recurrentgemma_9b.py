"""RecurrentGemma-9B — Griffin hybrid: RG-LRU recurrent blocks + local
attention in a 2:1 pattern. [arXiv:2402.19427]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    citation="arXiv:2402.19427 (Griffin/RecurrentGemma)",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    rglru_block_pattern=("recurrent", "recurrent", "attention"),
    rnn_width=4096,
    local_window=2048,
    activation="gelu",
    norm="rmsnorm",
    tie_embeddings=True,
)
