"""Whisper-tiny — encoder-decoder audio transformer backbone; mel+conv
frontend is STUBBED per assignment (input_specs provides frame embeddings).
[arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    arch_type="audio",
    citation="arXiv:2212.04356 (Whisper)",
    num_layers=4,
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51_865,
    encoder_frames=1500,
    max_target_positions=448,
    learned_positions=True,
    norm="layernorm",
    activation="gelu",
    tie_embeddings=True,
)
