"""StableLM-2-12B — dense decoder, GQA kv=8. [hf:stabilityai/stablelm-2-1_6b]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    arch_type="dense",
    citation="hf:stabilityai/stablelm-2-1_6b (family card)",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100_352,
    norm="layernorm",
    activation="silu",
)
