"""Config system for the AsyncFlow reproduction.

A single frozen dataclass describes every supported architecture family:
dense (GQA/MHA/MLA), MoE, SSM (mamba-1), hybrid (RG-LRU + local attention),
encoder-decoder (whisper) and VLM (vision-stub + LM backbone).

Configs are plain data — models are built from them in ``repro.models.model``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes assigned to this paper (global, before sharding).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``arch_type`` selects the block assembly:
      dense   — homogeneous decoder blocks (attention + MLP)
      moe     — decoder blocks with MoE FFN (optionally shared experts)
      ssm     — attention-free mamba-1 blocks
      hybrid  — Griffin pattern: (recurrent, recurrent, local-attention) tiles
      audio   — whisper-style encoder-decoder (conv frontend stubbed)
      vlm     — LM backbone consuming stubbed vision patch embeddings
    """

    name: str
    arch_type: str  # dense | moe | ssm | hybrid | audio | vlm
    citation: str

    num_layers: int = 12
    d_model: int = 1024
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 0  # 0 -> d_model // num_heads
    d_ff: int = 4096
    vocab_size: int = 32000

    # attention details
    attention: str = "gqa"  # gqa | mla
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0  # 0 = full attention; >0 = window (tokens)
    local_window: int = 2048  # hybrid local-attention window
    # long-context decode policy: window applied only for the long_500k shape
    long_context_window: int = 16_384

    # MLA (DeepSeek-V2 / MiniCPM3 style)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # expert hidden dim (deepseek-style fine-grained)
    first_dense_layers: int = 0  # deepseek: first k layers dense
    router_aux_coef: float = 0.01
    moe_device_limit: int = 0  # >0: route each token to <=M device groups
    moe_ep_degree: int = 16    # device groups for device-limited routing

    # SSM (mamba-1)
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0  # 0 -> ceil(d_model/16)
    ssm_chunk: int = 0    # >0: chunked selective scan (§Perf HC1)

    # hybrid (RG-LRU)
    rglru_block_pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")
    rnn_width: int = 0  # 0 -> d_model

    # enc-dec (audio)
    encoder_layers: int = 0
    encoder_frames: int = 1500  # whisper 30s @ 50Hz after conv stride 2
    max_target_positions: int = 448
    learned_positions: bool = False

    # vlm
    vision_tokens: int = 1024  # stubbed patch embeddings per image
    vision_embed_dim: int = 0  # 0 -> d_model (projector output)

    # norm / activations / embeddings
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    activation: str = "silu"  # silu (swiglu) | gelu
    tie_embeddings: bool = False

    # training
    lr_schedule: str = "cosine"  # cosine | wsd
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.rnn_width == 0:
            object.__setattr__(self, "rnn_width", self.d_model)
        if self.vision_embed_dim == 0:
            object.__setattr__(self, "vision_embed_dim", self.d_model)
        if self.ssm_dt_rank == 0:
            object.__setattr__(self, "ssm_dt_rank", max(1, (self.d_model + 15) // 16))
        if self.arch_type == "moe" and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # -- derived quantities -------------------------------------------------

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """long_500k applicability: SSM/hybrid natively; dense via the
        sliding-window variant; enc-dec (whisper) skipped (448 positions)."""
        return self.arch_type != "audio"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def param_count(self) -> int:
        """Total parameter count (all experts)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top_k experts)."""
        return _param_count(self, active_only=True)

    def reduced(self) -> "ModelConfig":
        """A small same-family variant for CPU smoke tests."""
        changes = dict(
            num_layers=2,
            d_model=min(self.d_model, 256),
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            num_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads * 4 // self.num_heads)),
            head_dim=64,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_frames=32 if self.arch_type == "audio" else self.encoder_frames,
            vision_tokens=16 if self.arch_type == "vlm" else self.vision_tokens,
            local_window=64,
            long_context_window=64,
            rnn_width=0,  # re-derived from reduced d_model in __post_init__
        )
        if self.num_experts:
            changes.update(
                num_experts=4,
                top_k=min(2, self.top_k),
                moe_d_ff=128,
                num_shared_experts=min(1, self.num_shared_experts),
                first_dense_layers=min(1, self.first_dense_layers),
            )
        if self.attention == "mla":
            changes.update(
                kv_lora_rank=64, q_lora_rank=0,
                qk_rope_head_dim=32, qk_nope_head_dim=32, v_head_dim=32,
            )
        return dataclasses.replace(self, **changes)


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    d, hd = cfg.d_model, cfg.head_dim
    nh, nkv = cfg.num_heads, cfg.num_kv_heads
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)

    if cfg.attention == "mla":
        q_dim = nh * (cfg.qk_rope_head_dim + cfg.qk_nope_head_dim)
        attn = d * q_dim  # q proj (no q_lora here unless set)
        if cfg.q_lora_rank:
            attn = d * cfg.q_lora_rank + cfg.q_lora_rank * q_dim
        attn += d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)  # kv down + k_rope
        attn += cfg.kv_lora_rank * nh * (cfg.qk_nope_head_dim + cfg.v_head_dim)
        attn += nh * cfg.v_head_dim * d  # o proj
    else:
        attn = d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d

    def mlp_params(dff: int) -> int:
        mult = 3 if cfg.activation == "silu" else 2  # swiglu has gate
        return mult * d * dff

    if cfg.arch_type == "ssm":
        di, ds = cfg.d_inner, cfg.ssm_state
        blk = d * 2 * di + di * cfg.ssm_conv + di * (cfg.ssm_dt_rank + 2 * ds)
        blk += cfg.ssm_dt_rank * di + di * ds + di + di * d
        return emb + cfg.num_layers * blk

    if cfg.arch_type == "hybrid":
        w = cfg.rnn_width
        rec = d * 2 * w + w * 4 + 2 * w + w * d  # in-proj x2, conv-ish gates, out
        att = attn
        n_rec = sum(1 for _ in range(cfg.num_layers)
                    if cfg.rglru_block_pattern[_ % len(cfg.rglru_block_pattern)] == "recurrent")
        n_att = cfg.num_layers - n_rec
        return emb + n_rec * (rec + mlp_params(cfg.d_ff)) + n_att * (att + mlp_params(cfg.d_ff))

    if cfg.arch_type == "moe":
        dense_layers = cfg.first_dense_layers
        moe_layers = cfg.num_layers - dense_layers
        router = d * cfg.num_experts
        shared = cfg.num_shared_experts * mlp_params(cfg.moe_d_ff)
        experts_total = cfg.num_experts * mlp_params(cfg.moe_d_ff)
        experts_active = cfg.top_k * mlp_params(cfg.moe_d_ff)
        per_moe = attn + router + shared + (experts_active if active_only else experts_total)
        per_dense = attn + mlp_params(cfg.d_ff)
        return emb + moe_layers * per_moe + dense_layers * per_dense

    # dense / vlm / audio decoder
    per = attn + mlp_params(cfg.d_ff)
    n = cfg.num_layers
    total = emb + n * per
    if cfg.arch_type == "audio":
        enc_attn = 4 * d * d
        total += cfg.encoder_layers * (enc_attn + mlp_params(cfg.d_ff))
        total += cfg.num_layers * (4 * d * d)  # cross attention
    return total
