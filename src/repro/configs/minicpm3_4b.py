"""MiniCPM3-4B — dense decoder with Multi-head Latent Attention (MLA).
[hf:openbmb/MiniCPM3-4B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    arch_type="dense",
    citation="hf:openbmb/MiniCPM3-4B",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73_448,
    attention="mla",
    kv_lora_rank=256,
    q_lora_rank=768,
    qk_rope_head_dim=32,
    qk_nope_head_dim=64,
    v_head_dim=64,
    tie_embeddings=True,
)
