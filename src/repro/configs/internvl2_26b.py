"""InternVL2-26B — VLM: InternViT vision encoder (STUBBED; input_specs
provides projected patch embeddings) + InternLM2-20B language backbone.
[arXiv:2404.16821]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    arch_type="vlm",
    citation="arXiv:2404.16821 (InternVL2)",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92_553,
    vision_tokens=1024,
)
