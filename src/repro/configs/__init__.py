"""Architecture registry.

Every assigned architecture is a ``src/repro/configs/<id>.py`` module
exporting ``CONFIG``; the registry maps ``--arch`` ids to them.
"""
from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig  # noqa: F401

ARCH_IDS = [
    "recurrentgemma_9b",
    "stablelm_12b",
    "minicpm3_4b",
    "grok_1_314b",
    "whisper_tiny",
    "minicpm_2b",
    "qwen1_5_32b",
    "falcon_mamba_7b",
    "deepseek_v2_236b",
    "internvl2_26b",
    # paper's own evaluation models (Qwen2.5 series)
    "qwen2_5_7b",
    "qwen2_5_32b",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
