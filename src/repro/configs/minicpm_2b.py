"""MiniCPM-2B — llama-like dense decoder trained with the WSD
(warmup-stable-decay) schedule. [arXiv:2404.06395]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    arch_type="dense",
    citation="arXiv:2404.06395 (MiniCPM)",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122_753,
    lr_schedule="wsd",
    tie_embeddings=True,
)
