"""User-level interface: the RL algorithm controller (paper §5.1).

``Trainer`` is the single entry point researchers modify: it owns the
algorithm choice (GRPO/PPO), builds engines through the backend adapters,
and runs the post-training workflow in any of the three modes. Minimal
config in, WorkflowResult out.

``TrainerConfig(algorithm=...)`` selects a registered streaming dataflow
(``rl/grpo.py`` / ``rl/ppo.py`` declare the built-ins; custom graphs
register through :func:`repro.core.workflow.register_dataflow` or the
service API) and compiles it onto one shared TransferQueue via
:class:`StageRunner` — every RL task (generate, ref_inference, reward,
advantage, actor/critic update) streams as its own pipeline stage.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional

import jax

from repro.configs import get_config
from repro.core.workflow import StageRunner, WorkflowConfig, build_dataflow
from repro.data import PromptDataset
from repro.data.tokenizer import ByteTokenizer
from repro.engines import JaxCriticEngine, JaxRolloutEngine, JaxTrainEngine
from repro.models import init_params
from repro.rl.grpo import GRPOConfig
from repro.rl.ppo import PPOConfig, init_critic_params
from repro.training.optimizer import OptimizerConfig


@dataclasses.dataclass
class TrainerConfig:
    arch: str = "qwen2_5_7b"
    reduced: bool = True               # CPU-scale variant
    algorithm: str = "grpo"            # any registered dataflow (grpo | ppo)
    mode: str = "async"                # baseline | streaming | async
    num_steps: int = 8
    prompts_per_step: int = 4
    group_size: int = 4
    max_new_tokens: int = 8
    rollout_workers: int = 2
    rollout_batch: int = 2
    train_micro_batch: int = 4
    staleness: int = 1
    staggered: bool = False
    lr: float = 3e-4
    seed: int = 0
    seq_len: int = 32
    policy: Any = "fifo"       # str, or {task: str} per consumer stage
    num_storage_units: int = 2
    reward: str = "exact"              # exact | shaped
    kl_coef: float = 0.0               # >0: adds the ref_inference stage
    chunk_tokens: int = 0              # >0: partial rollout (k1.5-style)
    rollout_backend: str = "fixed"     # fixed | continuous (slot batcher)
    cb_slots: int = 4                  # continuous backend: decode slots
    cb_page_size: int = 8              # continuous backend: KV page size
    use_pallas: bool = False           # fused Pallas RL-loss kernel in the
                                       # actor update (interpret off-TPU)
    gamma: float = 1.0                 # PPO/GAE discount
    gae_lambda: float = 0.95           # PPO/GAE lambda
    checkpoint_dir: str = ""           # run-snapshot dir; also gets a
                                       # legacy "<dir>/final" state dump
    checkpoint_interval_steps: int = 1  # snapshot every N steps (0 = only
                                        # run start/end + failure)
    checkpoint_keep_last: int = 3      # snapshot retention (keep-last-k)
    supervise_trainer: bool = True     # warm trainer restart on crash
    max_trainer_restarts: int = 4      # warm-restart budget
    channel_bandwidth_gbps: float = 0.0  # simulated host-net weight path
    metrics_jsonl: str = ""            # periodic metrics snapshots (JSONL)
    metrics_interval_s: float = 0.25   # sampler cadence when enabled
    auto_size_workers: bool = False    # planner-size stages left at 0
    elastic_interval_s: float = 0.0    # >0: live rebalance cadence (s)
    max_stage_workers: int = 8         # auto-size / elastic pool cap
    # -- supervision & fault tolerance --------------------------------
    supervise: bool = True             # generator-fleet crash recovery
    max_replica_restarts: int = 8      # fleet-wide respawn budget
    heartbeat_timeout_s: float = 10.0  # hung-replica detection threshold
    max_stage_retries: int = 2         # retryable-error attempts on top
    retry_backoff_s: float = 0.05      # base exponential backoff
    faults: Optional[Any] = None       # FaultConfig: chaos injection


class Trainer:
    """from repro.api import Trainer; Trainer(TrainerConfig()).fit()"""

    def __init__(self, tcfg: TrainerConfig,
                 model_cfg=None, params=None):
        self.tcfg = tcfg
        cfg = model_cfg or get_config(tcfg.arch)
        if tcfg.reduced and model_cfg is None:
            cfg = dataclasses.replace(
                cfg.reduced(), vocab_size=ByteTokenizer.vocab_size)
        self.cfg = cfg
        if params is None:
            params = init_params(jax.random.PRNGKey(tcfg.seed), cfg)
        from repro.rl.reward import math_reward, math_reward_shaped
        ref_params = None
        if tcfg.kl_coef > 0:
            ref_params = jax.tree.map(lambda a: a.copy(), params)
        self.rollout_engine = JaxRolloutEngine(
            cfg, group_size=tcfg.group_size,
            max_new_tokens=tcfg.max_new_tokens,
            reward_fn=(math_reward_shaped if tcfg.reward == "shaped"
                       else math_reward),
            ref_params=ref_params, chunk_tokens=tcfg.chunk_tokens,
            backend=tcfg.rollout_backend, cb_slots=tcfg.cb_slots,
            cb_page_size=tcfg.cb_page_size, cb_seed=tcfg.seed)
        opt = OptimizerConfig(lr=tcfg.lr, warmup_steps=2,
                              total_steps=tcfg.num_steps,
                              schedule=cfg.lr_schedule
                              if cfg.lr_schedule != "cosine" else "constant")
        global_batch = tcfg.prompts_per_step * tcfg.group_size
        if tcfg.algorithm == "ppo":
            rl_cfg = PPOConfig(kl_coef=tcfg.kl_coef,
                               use_pallas_logprob=tcfg.use_pallas)
            self.train_engine = JaxTrainEngine(
                cfg, params, rl=rl_cfg, opt=opt, algorithm="ppo",
                global_batch=global_batch, seq_len=tcfg.seq_len)
            self.critic_engine = JaxCriticEngine(
                cfg, init_critic_params(jax.random.PRNGKey(tcfg.seed + 1),
                                        cfg),
                rl=rl_cfg, opt=opt, global_batch=global_batch,
                seq_len=tcfg.seq_len)
        else:
            self.train_engine = JaxTrainEngine(
                cfg, params,
                rl=GRPOConfig(kl_coef=tcfg.kl_coef,
                              use_pallas_logprob=tcfg.use_pallas),
                opt=opt, global_batch=global_batch, seq_len=tcfg.seq_len)
            self.critic_engine = None
        self.engines = {"rollout": self.rollout_engine,
                        "actor": self.train_engine}
        if self.critic_engine is not None:
            self.engines["critic"] = self.critic_engine
        self.dataset = PromptDataset(seed=tcfg.seed)

    def fit(self, resume: Optional[str] = None):
        """Run the workflow; the returned ``WorkflowResult`` carries the
        full telemetry dict (per-stage table, busy/wait fractions,
        staleness quantiles, raw metrics snapshot) — render it with
        :func:`repro.core.obs.render_report`.

        ``resume="auto"`` (or an explicit snapshot path) cold-resumes a
        killed run from its newest intact run snapshot under
        ``checkpoint_dir``: engine states, the published weight version,
        rollout sampling bases and the dataset cursor are restored, so a
        fixed-seed resumed run reproduces the uninterrupted run's metrics
        bit-for-bit (synchronous/streaming modes). ``"auto"`` with no
        snapshot on disk silently starts fresh; an explicit path that is
        missing or torn raises."""
        t = self.tcfg
        resume_doc = None
        if resume:
            resume_doc = self._load_resume(resume)
        wcfg = WorkflowConfig(
            mode=t.mode, num_rollout_workers=t.rollout_workers,
            rollout_batch=t.rollout_batch,
            train_micro_batch=t.train_micro_batch,
            prompts_per_step=t.prompts_per_step, group_size=t.group_size,
            num_steps=t.num_steps, staleness=t.staleness,
            staggered=t.staggered, policy=t.policy,
            num_storage_units=t.num_storage_units,
            channel_bandwidth_gbps=t.channel_bandwidth_gbps,
            metrics_jsonl=t.metrics_jsonl,
            metrics_interval_s=t.metrics_interval_s,
            auto_size_workers=t.auto_size_workers,
            elastic_interval_s=t.elastic_interval_s,
            max_stage_workers=t.max_stage_workers,
            supervise=t.supervise,
            max_replica_restarts=t.max_replica_restarts,
            heartbeat_timeout_s=t.heartbeat_timeout_s,
            max_stage_retries=t.max_stage_retries,
            retry_backoff_s=t.retry_backoff_s, faults=t.faults,
            checkpoint_dir=t.checkpoint_dir,
            checkpoint_interval_steps=t.checkpoint_interval_steps,
            checkpoint_keep_last=t.checkpoint_keep_last,
            supervise_trainer=t.supervise_trainer,
            max_trainer_restarts=t.max_trainer_restarts)
        graph = build_dataflow(t.algorithm, kl_coef=t.kl_coef,
                               gamma=t.gamma, lam=t.gae_lambda)
        runner = StageRunner(
            wcfg, graph, engines=self.engines,
            prompt_stream=lambda s: self.dataset.prompts_for_step(
                s, t.prompts_per_step),
            resume=resume_doc)
        result = runner.run()
        if t.checkpoint_dir:
            # legacy single-state dump alongside the run snapshots (the
            # snapshots own the directory root)
            from repro.training import save_checkpoint
            save_checkpoint(os.path.join(t.checkpoint_dir, "final"),
                            self.train_engine.state,
                            step=int(self.train_engine.state.step))
        return result

    def _load_resume(self, resume: str) -> Optional[dict]:
        """Resolve + load a run snapshot and restore engine/rollout state
        in place; returns the run-state doc handed to the StageRunner."""
        t = self.tcfg
        if not t.checkpoint_dir and resume == "auto":
            return None
        from repro.core.recovery import RunCheckpointer
        ckpt = RunCheckpointer(t.checkpoint_dir or ".",
                               keep_last=t.checkpoint_keep_last)
        path = ckpt.resolve(resume)
        if path is None:
            return None                 # auto + nothing intact: fresh run
        doc = ckpt.load(path)
        step = int(doc["step"])
        for key, eng in ((k, e) for k, e in self.engines.items()
                         if hasattr(e, "state")):
            if key in doc.get("engines", []):
                eng.state, _ = ckpt.load_engine(path, key, eng.state)
                if hasattr(eng, "version"):
                    eng.version = step
        roll = doc.get("rollout") or {}
        self.rollout_engine._gid = int(roll.get("gid", 0))
        self.rollout_engine.cb_uid_start = int(roll.get("cb_next_uid", 0))
        return doc

    def restore(self, path: str) -> int:
        """Load a checkpoint into the training engine; returns the step."""
        from repro.training import restore_checkpoint
        state, step = restore_checkpoint(path, self.train_engine.state)
        self.train_engine.state = state
        return step
