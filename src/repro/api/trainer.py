"""User-level interface: the RL algorithm controller (paper §5.1).

``Trainer`` is the single entry point researchers modify: it owns the
algorithm choice (GRPO/PPO), builds engines through the backend adapters,
and runs the post-training workflow in any of the three modes. Minimal
config in, WorkflowResult out.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro.configs import get_config
from repro.core.workflow import AsyncRLRunner, WorkflowConfig
from repro.data import PromptDataset
from repro.data.tokenizer import ByteTokenizer
from repro.engines import JaxRolloutEngine, JaxTrainEngine
from repro.models import init_params
from repro.rl.grpo import GRPOConfig
from repro.training.optimizer import OptimizerConfig


@dataclasses.dataclass
class TrainerConfig:
    arch: str = "qwen2_5_7b"
    reduced: bool = True               # CPU-scale variant
    algorithm: str = "grpo"            # grpo | ppo
    mode: str = "async"                # baseline | streaming | async
    num_steps: int = 8
    prompts_per_step: int = 4
    group_size: int = 4
    max_new_tokens: int = 8
    rollout_workers: int = 2
    rollout_batch: int = 2
    train_micro_batch: int = 4
    staleness: int = 1
    staggered: bool = False
    lr: float = 3e-4
    seed: int = 0
    seq_len: int = 32
    policy: str = "fifo"
    reward: str = "exact"              # exact | shaped
    kl_coef: float = 0.0               # >0: GRPO+KL with a frozen reference
    chunk_tokens: int = 0              # >0: partial rollout (k1.5-style)
    checkpoint_dir: str = ""           # save final state when set
    channel_bandwidth_gbps: float = 0.0  # simulated host-net weight path


class Trainer:
    """from repro.api import Trainer; Trainer(TrainerConfig()).fit()"""

    def __init__(self, tcfg: TrainerConfig,
                 model_cfg=None, params=None):
        self.tcfg = tcfg
        cfg = model_cfg or get_config(tcfg.arch)
        if tcfg.reduced and model_cfg is None:
            cfg = dataclasses.replace(
                cfg.reduced(), vocab_size=ByteTokenizer.vocab_size)
        self.cfg = cfg
        if params is None:
            params = init_params(jax.random.PRNGKey(tcfg.seed), cfg)
        from repro.rl.reward import math_reward, math_reward_shaped
        ref_params = None
        if tcfg.kl_coef > 0:
            ref_params = jax.tree.map(lambda a: a.copy(), params)
        self.rollout_engine = JaxRolloutEngine(
            cfg, group_size=tcfg.group_size,
            max_new_tokens=tcfg.max_new_tokens,
            reward_fn=(math_reward_shaped if tcfg.reward == "shaped"
                       else math_reward),
            ref_params=ref_params, chunk_tokens=tcfg.chunk_tokens)
        self.train_engine = JaxTrainEngine(
            cfg, params, rl=GRPOConfig(kl_coef=tcfg.kl_coef),
            opt=OptimizerConfig(lr=tcfg.lr, warmup_steps=2,
                                total_steps=tcfg.num_steps,
                                schedule=cfg.lr_schedule
                                if cfg.lr_schedule != "cosine" else "constant"),
            global_batch=tcfg.prompts_per_step * tcfg.group_size,
            seq_len=tcfg.seq_len)
        self.dataset = PromptDataset(seed=tcfg.seed)

    def fit(self):
        t = self.tcfg
        wcfg = WorkflowConfig(
            mode=t.mode, num_rollout_workers=t.rollout_workers,
            rollout_batch=t.rollout_batch,
            train_micro_batch=t.train_micro_batch,
            prompts_per_step=t.prompts_per_step, group_size=t.group_size,
            num_steps=t.num_steps, staleness=t.staleness,
            staggered=t.staggered, policy=t.policy,
            channel_bandwidth_gbps=t.channel_bandwidth_gbps,
            extra_columns=("ref_logprob",) if t.kl_coef > 0 else ())
        runner = AsyncRLRunner(
            wcfg, rollout_engine=self.rollout_engine,
            train_engine=self.train_engine,
            prompt_stream=lambda s: self.dataset.prompts_for_step(
                s, t.prompts_per_step))
        result = runner.run()
        if t.checkpoint_dir:
            from repro.training import save_checkpoint
            save_checkpoint(t.checkpoint_dir, self.train_engine.state,
                            step=int(self.train_engine.state.step))
        return result

    def restore(self, path: str) -> int:
        """Load a checkpoint into the training engine; returns the step."""
        from repro.training import restore_checkpoint
        state, step = restore_checkpoint(path, self.train_engine.state)
        self.train_engine.state = state
        return step
