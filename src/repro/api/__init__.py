from repro.api.service import AsyncFlowService
from repro.api.trainer import Trainer, TrainerConfig

__all__ = ["Trainer", "TrainerConfig", "AsyncFlowService"]
