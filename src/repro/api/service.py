"""Service-oriented user interface (paper §5.1).

The key APIs the paper lists for industrial workflow automation:
  init_engines, put_prompts_data, put_experience_data,
  get_experience_data, weight_sync_notify
exposed over the in-process service object (an RPC layer would wrap this
1:1 on a real cluster — the surface is the contribution, not the wire).

Workflow automation on top of the stage-graph subsystem: services can
``register_dataflow`` custom algorithm graphs, ``register_stage`` extra
streaming tasks onto an existing graph (e.g. a filtering or auxiliary
scoring stage), and ``run_dataflow`` to compile a graph onto one shared
TransferQueue and drive it under any workflow mode.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Union

from repro.core.obs import get_registry, render_report
from repro.core.transfer_queue import TransferQueue
from repro.core.workflow.stage_graph import (StageGraph, StageRunner,
                                             StageSpec, WorkflowConfig,
                                             build_dataflow,
                                             register_dataflow)
from repro.core.workflow.weight_sync import (WeightChannel, WeightReceiver,
                                             WeightSender)
from repro.engines.adapter import EngineRegistry


class AsyncFlowService:
    """Single service endpoint orchestrating engines, TransferQueue and
    weight synchronization."""

    def __init__(self):
        self.engines: Dict[str, Any] = {}
        self.queues: Dict[str, TransferQueue] = {}
        self.channel = WeightChannel()
        self.sender: Optional[WeightSender] = None
        self.receivers: List[WeightReceiver] = []
        self._version = 0

    # -- paper §5.1 key APIs -------------------------------------------------

    def init_engines(self, specs: Dict[str, dict]) -> None:
        """specs: {"train": {"engine": "jax_train", ...kwargs},
                   "rollout": {"engine": "jax_rollout", ...}}"""
        for name, spec in specs.items():
            kw = dict(spec)
            engine = kw.pop("engine")
            self.engines[name] = EngineRegistry.create(engine, **kw)

    def create_queue(self, name: str, capacity: int,
                     tasks: Dict[str, Sequence[str]],
                     num_storage_units: int = 2, policy: str = "fifo"
                     ) -> TransferQueue:
        q = TransferQueue(capacity, tasks, num_storage_units, policy)
        self.queues[name] = q
        return q

    def put_prompts_data(self, queue: str, prompts: Sequence[Any]) -> List[int]:
        q = self.queues[queue]
        idxs = q.next_indices(len(prompts))
        q.put_batch(idxs, "prompt", list(prompts))
        return idxs

    def put_experience_data(self, queue: str, columns: Dict[str, Sequence],
                            token_lens: Optional[Sequence[int]] = None
                            ) -> List[int]:
        q = self.queues[queue]
        n = len(next(iter(columns.values())))
        idxs = q.next_indices(n)
        for col, vals in columns.items():
            q.put_batch(idxs, col, list(vals), token_lens=token_lens)
        return idxs

    def get_experience_data(self, queue: str, task: str, batch_size: int,
                            consumer: str = "dp0", timeout: float = None):
        return self.queues[queue].get(task, batch_size, consumer,
                                      timeout=timeout)

    def weight_sync_notify(self, params, version: Optional[int] = None) -> int:
        """Publish new weights to all registered receivers."""
        if self.sender is None:
            self.sender = WeightSender(self.channel, mode="async")
        self._version = version if version is not None else self._version + 1
        self.sender.publish(params, self._version)
        return self._version

    def register_receiver(self, init_params) -> WeightReceiver:
        r = WeightReceiver(self.channel, init_params, version=0)
        self.receivers.append(r)
        return r

    # -- telemetry (the monitoring surface an operator dashboard polls) ------

    def metrics_snapshot(self) -> Dict[str, dict]:
        """JSON-safe snapshot of the process-global metrics registry:
        queue depths, per-stage latency/throughput, weight-sync stats."""
        return get_registry().snapshot()

    def telemetry_report(self, result) -> str:
        """Render a finished run's per-stage telemetry table
        (``WorkflowResult.telemetry``) as fixed-width text."""
        return render_report(result.telemetry)

    # -- stage-graph workflow automation (§5.1) ------------------------------

    def register_dataflow(self, name: str,
                          builder: Callable[..., StageGraph]) -> None:
        """Register a custom algorithm dataflow (``builder(**kw) ->
        StageGraph``) selectable via ``TrainerConfig(algorithm=name)``."""
        register_dataflow(name, builder)

    def build_dataflow(self, name: str, **kw) -> StageGraph:
        return build_dataflow(name, **kw)

    def register_stage(self, graph: StageGraph, spec: StageSpec
                       ) -> StageGraph:
        """Attach a custom streaming task to an existing dataflow; the
        graph re-validates (topology checks) at run time."""
        return graph.add(spec)

    def run_dataflow(self, graph: Union[str, StageGraph],
                     cfg: WorkflowConfig, prompt_stream,
                     engines: Optional[Dict[str, Any]] = None, **kw):
        """Compile a dataflow onto one shared TransferQueue and run it.
        ``engines`` defaults to the engines created via init_engines."""
        if isinstance(graph, str):
            graph = build_dataflow(graph, **kw)
        runner = StageRunner(cfg, graph,
                             engines=engines or self.engines,
                             prompt_stream=prompt_stream)
        return runner.run()
