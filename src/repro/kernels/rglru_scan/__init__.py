from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref

__all__ = ["rglru_scan", "rglru_scan_ref"]
