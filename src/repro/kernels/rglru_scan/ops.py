"""jit'd wrapper for the RG-LRU scan kernel."""
import jax

from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.rglru_scan.rglru_scan import rglru_scan_kernel


def rglru_scan(a, b, *, block_s=256, block_w=128):
    B, S, W = a.shape
    bs, bw = min(block_s, S), min(block_w, W)
    if S % bs or W % bw:
        return rglru_scan_ref(a, b)
    return rglru_scan_kernel(a.astype("float32"), b.astype("float32"),
                             block_s=bs, block_w=bw,
                             interpret=jax.default_backend() != "tpu")
