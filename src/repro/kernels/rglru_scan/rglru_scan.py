"""RG-LRU linear recurrence — Pallas TPU kernel.

h_t = a_t * h_{t-1} + b_t   (elementwise over the width dim)

TPU adaptation: instead of a strictly sequential time loop (poor VPU
utilization), the sequence is blocked (BS timesteps per block); inside a
block we run a *log-depth associative scan* on (a, b) pairs, then splice in
the carried state h via  h_t = P_t * h_carry + S_t  where P_t is the
cumulative product of a. The carry lives in VMEM scratch across the
sequential time-block grid dim; the width dim is blocked to 128-lane
vector registers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h_out_ref, carry_ref):
    js = pl.program_id(2)

    @pl.when(js == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[0].astype(jnp.float32)    # (BS, BW)
    b = b_ref[0].astype(jnp.float32)

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    prod_a, s = jax.lax.associative_scan(comb, (a, b), axis=0)
    h = s + prod_a * carry_ref[...][None, :]
    h_out_ref[0] = h.astype(h_out_ref.dtype)
    carry_ref[...] = h[-1]


@functools.partial(jax.jit, static_argnames=("block_s", "block_w",
                                             "interpret"))
def rglru_scan_kernel(a, b, *, block_s=256, block_w=128, interpret=False):
    """a, b: (B, S, W) float32 -> h: (B, S, W) float32."""
    B, S, W = a.shape
    block_s = min(block_s, S)
    block_w = min(block_w, W)
    assert S % block_s == 0 and W % block_w == 0
    ns, nw = S // block_s, W // block_w

    return pl.pallas_call(
        _rglru_kernel,
        grid=(B, nw, ns),  # trailing dim (time blocks) is sequential
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda b_, w_, s_: (b_, s_, w_)),
            pl.BlockSpec((1, block_s, block_w), lambda b_, w_, s_: (b_, s_, w_)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w),
                               lambda b_, w_, s_: (b_, s_, w_)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(a, b)
