"""Pure-jnp oracle: sequential linear recurrence."""
import jax
import jax.numpy as jnp


def rglru_scan_ref(a, b):
    """h_t = a_t * h_{t-1} + b_t over axis 1. a, b: (B, S, W)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    a_t = jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    b_t = jnp.moveaxis(b.astype(jnp.float32), 1, 0)
    h0 = jnp.zeros_like(a_t[0])
    _, hs = jax.lax.scan(step, h0, (a_t, b_t))
    return jnp.moveaxis(hs, 0, 1)
