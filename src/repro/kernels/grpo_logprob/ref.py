"""Pure-jnp oracle for grpo_logprob."""
import jax
import jax.numpy as jnp


def grpo_logprob_ref(logits, targets):
    """logits: (N, V); targets: (N,) -> (logprob (N,), entropy (N,))."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    lp = jnp.take_along_axis(logp, targets[:, None], axis=1)[:, 0]
    ent = -jnp.sum(jnp.exp(logp) * logp, axis=-1)
    return lp, ent
