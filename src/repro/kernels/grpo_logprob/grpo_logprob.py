"""Fused token log-prob + entropy over large vocab logits — Pallas kernel.

The biggest tensor in the GRPO actor-update step is the logits
(B, S, V) with V up to 256k: computing log-softmax naively materializes a
second (B, S, V) array and is purely HBM-bandwidth bound. This kernel
streams vocab blocks through VMEM once, maintaining the online
log-sum-exp state plus two fused reductions:

  m, l        — running max / rescaled sum of exp (standard online LSE)
  t           — running Σ exp(x_i − m) · x_i (for entropy)
  g           — the target token's logit (picked up when its block streams by)

Outputs per token:  logprob = g − (m + log l),  entropy = (m + log l) − t/l.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pad_utils import (NEG_INF, pad_logits, pad_rows,
                                     pick_blocks)


def _kernel(logits_ref, target_ref, lp_ref, ent_ref, m_ref, l_ref, t_ref,
            g_ref, *, block_v, num_v_blocks):
    jv = pl.program_id(1)

    @pl.when(jv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        t_ref[...] = jnp.zeros_like(t_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    x = logits_ref[...].astype(jnp.float32)          # (BN, BV)
    tgt = target_ref[...]                            # (BN,)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, x.max(-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(x - m_new[:, None])
    l_ref[...] = alpha * l_ref[...] + p.sum(-1)
    t_ref[...] = alpha * t_ref[...] + (p * x).sum(-1)
    m_ref[...] = m_new

    # pick up the target logit if it lives in this vocab block
    v0 = jv * block_v
    local = tgt - v0
    in_block = (local >= 0) & (local < block_v)
    idx = jnp.clip(local, 0, block_v - 1)
    picked = jnp.take_along_axis(x, idx[:, None], axis=1)[:, 0]
    g_ref[...] = jnp.where(in_block, picked, g_ref[...])

    @pl.when(jv == num_v_blocks - 1)
    def _finish():
        lse = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
        lp_ref[...] = (g_ref[...] - lse).astype(lp_ref.dtype)
        ent_ref[...] = (lse - t_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                        ).astype(ent_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_v",
                                             "interpret"))
def grpo_logprob_kernel(logits, targets, *, block_n=256, block_v=2048,
                        interpret=False):
    """logits: (N, V); targets: (N,) int32 -> (logprob (N,), entropy (N,)).

    Any (N, V) works: rows pad with zeros (tail sliced off the outputs),
    vocab pads with NEG_INF (vanishes inside the online LSE).
    """
    N, V = logits.shape
    bn, bv, n_pad, v_pad = pick_blocks(N, V, block_n, block_v)
    nn, nv = n_pad // bn, v_pad // bv

    lg = pad_logits(logits, n_pad, v_pad)
    tg = pad_rows(targets, n_pad)

    kernel = functools.partial(_kernel, block_v=bv, num_v_blocks=nv)
    row = pl.BlockSpec((bn,), lambda i, j: (i,))
    lp, ent = pl.pallas_call(
        kernel,
        grid=(nn, nv),
        in_specs=[
            pl.BlockSpec((bn, bv), lambda i, j: (i, j)),
            row,
        ],
        out_specs=[row, row],
        out_shape=[jax.ShapeDtypeStruct((n_pad,), jnp.float32),
                   jax.ShapeDtypeStruct((n_pad,), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bn,), jnp.float32)] * 4,
        interpret=interpret,
    )(lg, tg)
    return lp[:N], ent[:N]
