"""jit'd wrapper; accepts (..., V) logits and (...) targets."""
import jax

from repro.kernels.grpo_logprob.grpo_logprob import grpo_logprob_kernel
from repro.kernels.grpo_logprob.ref import grpo_logprob_ref


def grpo_logprob(logits, targets, *, block_n=256, block_v=2048):
    shape = targets.shape
    V = logits.shape[-1]
    lg = logits.reshape(-1, V)
    tg = targets.reshape(-1)
    N = lg.shape[0]
    bn, bv = min(block_n, N), min(block_v, V)
    if N % bn or V % bv:
        lp, ent = grpo_logprob_ref(lg, tg)
    else:
        lp, ent = grpo_logprob_kernel(lg, tg, block_n=bn, block_v=bv,
                                      interpret=jax.default_backend() != "tpu")
    return lp.reshape(shape), ent.reshape(shape)
