"""jit'd wrapper; accepts (..., V) logits and (...) targets."""
import jax

from repro.kernels.grpo_logprob.grpo_logprob import grpo_logprob_kernel


def grpo_logprob(logits, targets, *, block_n=256, block_v=2048):
    shape = targets.shape
    V = logits.shape[-1]
    lg = logits.reshape(-1, V)
    tg = targets.reshape(-1)
    lp, ent = grpo_logprob_kernel(lg, tg, block_n=block_n, block_v=block_v,
                                  interpret=jax.default_backend() != "tpu")
    return lp.reshape(shape), ent.reshape(shape)
