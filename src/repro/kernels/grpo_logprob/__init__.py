from repro.kernels.grpo_logprob.ops import grpo_logprob
from repro.kernels.grpo_logprob.ref import grpo_logprob_ref

__all__ = ["grpo_logprob", "grpo_logprob_ref"]
