"""jit'd public wrapper: Pallas kernel on TPU, interpret-mode elsewhere,
falling back to the jnp oracle for shapes the kernel doesn't tile."""
import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


def _on_tpu():
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, window=0, block_q=128, block_k=128):
    Sq, Sk = q.shape[1], k.shape[1]
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    if Sq % bq or Sk % bk:
        return flash_attention_ref(q, k, v, window=window)
    return flash_attention_kernel(q, k, v, window=window, block_q=bq,
                                  block_k=bk, interpret=not _on_tpu())
