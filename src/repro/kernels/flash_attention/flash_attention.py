"""Blockwise causal GQA flash attention — Pallas TPU kernel.

TPU adaptation of the classic FlashAttention tiling:
  * grid = (batch, q_heads, num_q_blocks, num_k_blocks); the trailing grid
    dim runs sequentially on TPU, so the online-softmax state (m, l, acc)
    lives in VMEM scratch and carries across k-blocks.
  * Q block (BQ=128 rows) stays resident in VMEM; K/V stream through in
    BK=128-column blocks — MXU-aligned (head_dim multiples of 128 get full
    128x128 systolic utilization; smaller head dims still map via lane
    packing).
  * Softmax state in fp32 VREGs; inputs may be bf16.
  * Causal + optional sliding-window band masks applied per block; fully
    masked blocks still execute (no early-exit on TPU grids) but contribute
    zero weight.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q, block_k, num_k_blocks, window, scale):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (BQ, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (BK, hd)
    v = v_ref[0, 0].astype(jnp.float32)            # (BK, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (BQ,BK)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[...] + p.sum(-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == num_k_blocks - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                             "interpret"))
def flash_attention_kernel(q, k, v, *, window=0, block_q=128, block_k=128,
                           interpret=False):
    """q: (B, Sq, H, hd); k/v: (B, Sk, KVH, hd) — causal, optional window.

    Returns (B, Sq, H, hd), same dtype as q.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KVH, _ = k.shape
    n_rep = H // KVH
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    nq, nk = Sq // block_q, Sk // block_k
    scale = hd ** -0.5

    # layout: (B, H, S, hd) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, num_k_blocks=nk,
        window=window, scale=scale)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, n_rep=n_rep: (b, h // n_rep, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, n_rep=n_rep: (b, h // n_rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
