"""Pure-jnp oracle for flash_attention."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, window=0):
    """q: (B,Sq,H,hd); k/v: (B,Sk,KVH,hd). Causal (+ optional window)."""
    B, Sq, H, hd = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    if KVH != H:
        k = jnp.repeat(k, H // KVH, axis=2)
        v = jnp.repeat(v, H // KVH, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)).astype(q.dtype)
