"""Pure-jnp oracle: sequential selective scan."""
import jax
import jax.numpy as jnp


def mamba_scan_ref(x, dt, a, b, c):
    """x, dt: (B,S,D); a: (D,N); b,c: (B,S,N) -> y (B,S,D) float32."""
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    bf, cf = b.astype(jnp.float32), c.astype(jnp.float32)
    af = a.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp
        da = jnp.exp(dtt[:, :, None] * af[None])          # (B,D,N)
        h = da * h + (dtt * xt)[:, :, None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    B, S, D = x.shape
    h0 = jnp.zeros((B, D, af.shape[1]), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (jnp.moveaxis(xf, 1, 0),
                                    jnp.moveaxis(dtf, 1, 0),
                                    jnp.moveaxis(bf, 1, 0),
                                    jnp.moveaxis(cf, 1, 0)))
    return jnp.moveaxis(ys, 0, 1)
