"""jit'd wrapper for the mamba selective-scan kernel."""
import jax

from repro.kernels.mamba_scan.mamba_scan import mamba_scan_kernel
from repro.kernels.mamba_scan.ref import mamba_scan_ref


def mamba_scan(x, dt, a, b, c, *, block_s=128, block_d=128):
    B, S, D = x.shape
    bs, bd = min(block_s, S), min(block_d, D)
    if S % bs or D % bd:
        return mamba_scan_ref(x, dt, a, b, c)
    return mamba_scan_kernel(x, dt, a, b, c, block_s=bs, block_d=bd,
                             interpret=jax.default_backend() != "tpu")
