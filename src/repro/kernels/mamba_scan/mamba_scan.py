"""Mamba-1 selective scan — Pallas TPU kernel.

h_t = exp(dt_t ⊙ A) ⊙ h_{t-1} + (dt_t ⊙ x_t) ⊗ B_t,   y_t = h_t · C_t

State is (channels, ssm_state). TPU adaptation mirrors rglru_scan: time is
blocked along the sequential grid dim with the (BD, N) state carried in
VMEM scratch; channels are blocked to 128 lanes; within a time block a
log-depth associative scan runs over (da, dbx) with the small state dim
(N=16) kept fully resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref):
    js = pl.program_id(2)

    @pl.when(js == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)     # (BS, BD)
    dt = dt_ref[0].astype(jnp.float32)   # (BS, BD)
    a = a_ref[...].astype(jnp.float32)   # (BD, N)
    b = b_ref[0].astype(jnp.float32)     # (BS, N)
    c = c_ref[0].astype(jnp.float32)     # (BS, N)

    da = jnp.exp(dt[:, :, None] * a[None])            # (BS, BD, N)
    dbx = (dt * x)[:, :, None] * b[:, None, :]        # (BS, BD, N)

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    prod_a, s = jax.lax.associative_scan(comb, (da, dbx), axis=0)
    h = s + prod_a * h_ref[...][None]
    y = jnp.einsum("sdn,sn->sd", h, c)
    y_ref[0] = y.astype(y_ref.dtype)
    h_ref[...] = h[-1]


@functools.partial(jax.jit, static_argnames=("block_s", "block_d",
                                             "interpret"))
def mamba_scan_kernel(x, dt, a, b, c, *, block_s=128, block_d=128,
                      interpret=False):
    """x, dt: (B,S,D); a: (D,N); b, c: (B,S,N) -> y: (B,S,D) float32."""
    B, S, D = x.shape
    N = a.shape[1]
    block_s = min(block_s, S)
    block_d = min(block_d, D)
    assert S % block_s == 0 and D % block_d == 0
    ns, nd = S // block_s, D // block_d

    return pl.pallas_call(
        _mamba_kernel,
        grid=(B, nd, ns),
        in_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda b_, d_, s_: (b_, s_, d_)),
            pl.BlockSpec((1, block_s, block_d), lambda b_, d_, s_: (b_, s_, d_)),
            pl.BlockSpec((block_d, N), lambda b_, d_, s_: (d_, 0)),
            pl.BlockSpec((1, block_s, N), lambda b_, d_, s_: (b_, s_, 0)),
            pl.BlockSpec((1, block_s, N), lambda b_, d_, s_: (b_, s_, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_d),
                               lambda b_, d_, s_: (b_, s_, d_)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
