"""Pure-jnp oracle for the fused RL loss — autodiff-able, materializes
the full (N, V) log-softmax. This is what the fused kernel must match
(values and, via ``jax.grad``, gradients)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_rl_loss_ref(logits, targets, old_logprob, ref_logprob, advantage,
                      *, clip_eps=0.2):
    """logits (N, V), the rest (N,) -> (lp, ent, kl, pl, ratio), each (N,)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    lp = jnp.take_along_axis(logp, targets[:, None], axis=-1)[:, 0]
    ent = -(jnp.exp(logp) * logp).sum(-1)

    old = old_logprob.astype(jnp.float32)
    ref = ref_logprob.astype(jnp.float32)
    adv = advantage.astype(jnp.float32)

    ratio = jnp.exp(lp - old)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    pl_tok = -jnp.minimum(unclipped, clipped)
    d = ref - lp
    kl = jnp.exp(d) - d - 1.0
    return lp, ent, kl, pl_tok, ratio
