"""Public fused RL-loss op with a hand-written VJP.

``fused_rl_loss`` computes the per-token actor hot path

  lp, ent, kl, pl, ratio = f(logits, targets, old_lp, ref_lp, adv)

in ONE streamed pass over the (N, V) logits forward, and one more pass
backward — recomputing per-block softmax from the saved (N,) statistics
(lse, x̄) instead of materializing a log-softmax residual, which is what
autodiff through the unfused composition does.

Both routes share the same ``jax.custom_vjp``:

  * ``use_pallas=True``  — the Pallas kernels in ``fused_rl_loss.py``
    (interpret mode off-TPU), pad-and-mask for any (N, V).
  * ``use_pallas=False`` — an equivalent one-pass jnp forward/backward,
    so even the pure-XLA route skips the autodiff residual.

Chain-rule scalars (shared by both backward routes); with
``d = ref − lp``, ``sel`` = unclipped branch active, ``in_clip`` =
ratio inside the clip interval:

  ∂pl/∂lp    = −where(sel, ratio·A, ratio·A·in_clip)
  ∂kl/∂lp    = 1 − exp(d)
  ∂ratio/∂lp = ratio

  dlp   = g_pl·∂pl/∂lp + g_kl·(1 − exp(d)) + g_ratio·ratio + g_lp
  dx_j  = dlp·δ_jt − p_j (dlp + g_ent·(x_j − x̄))
  g_old = −g_pl·∂pl/∂lp − g_ratio·ratio
  g_ref = g_kl·(exp(d) − 1)
  g_adv = −g_pl·where(sel, ratio, clip(ratio))
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fused_rl_loss.fused_rl_loss import (
    fused_rl_loss_bwd_kernel, fused_rl_loss_fwd_kernel)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _epilogue(lp, ent, old, ref, adv, clip_eps):
    ratio = jnp.exp(lp - old)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    pl_tok = -jnp.minimum(unclipped, clipped)
    d = ref - lp
    kl = jnp.exp(d) - d - 1.0
    return lp, ent, kl, pl_tok, ratio


def _fwd_jnp(logits, targets, old, ref, adv, clip_eps):
    """One-pass jnp forward: lse/entropy/target pick without log_softmax."""
    x = logits.astype(jnp.float32)
    m = x.max(-1)
    s = jnp.exp(x - m[:, None])
    l = s.sum(-1)
    lse = m + jnp.log(l)
    g = jnp.take_along_axis(x, targets[:, None], axis=-1)[:, 0]
    lp = g - lse
    ent = lse - (s * x).sum(-1) / l
    return _epilogue(lp, ent, old.astype(jnp.float32),
                     ref.astype(jnp.float32), adv.astype(jnp.float32),
                     clip_eps), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _fused(logits, targets, old_lp, ref_lp, adv,
           clip_eps, use_pallas, block_n, block_v):
    if use_pallas:
        lp, ent, kl, pl_tok, ratio, _lse = fused_rl_loss_fwd_kernel(
            logits, targets, old_lp, ref_lp, adv, clip_eps=clip_eps,
            block_n=block_n, block_v=block_v, interpret=_interpret())
        return lp, ent, kl, pl_tok, ratio
    outs, _lse = _fwd_jnp(logits, targets, old_lp, ref_lp, adv, clip_eps)
    return outs


def _fused_fwd(logits, targets, old_lp, ref_lp, adv,
               clip_eps, use_pallas, block_n, block_v):
    if use_pallas:
        lp, ent, kl, pl_tok, ratio, lse = fused_rl_loss_fwd_kernel(
            logits, targets, old_lp, ref_lp, adv, clip_eps=clip_eps,
            block_n=block_n, block_v=block_v, interpret=_interpret())
        outs = (lp, ent, kl, pl_tok, ratio)
    else:
        outs, lse = _fwd_jnp(logits, targets, old_lp, ref_lp, adv, clip_eps)
        lp, ent = outs[0], outs[1]
    res = (logits, targets, old_lp, ref_lp, adv, lp, ent, lse)
    return outs, res


def _fused_bwd(clip_eps, use_pallas, block_n, block_v, res, cts):
    logits, targets, old_lp, ref_lp, adv, lp, ent, lse = res
    g_lp, g_ent, g_kl, g_pl, g_ratio = cts

    old = old_lp.astype(jnp.float32)
    ref = ref_lp.astype(jnp.float32)
    a = adv.astype(jnp.float32)

    ratio = jnp.exp(lp - old)
    clip_r = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
    unclipped = ratio * a
    # jnp.minimum ties pick the first operand — keep the same convention
    sel = unclipped <= clip_r * a
    in_clip = (ratio >= 1.0 - clip_eps) & (ratio <= 1.0 + clip_eps)
    dpl_dlp = -jnp.where(sel, unclipped,
                         unclipped * in_clip.astype(jnp.float32))
    expd = jnp.exp(ref - lp)

    dlp = (g_pl * dpl_dlp + g_kl * (1.0 - expd)
           + g_ratio * ratio + g_lp)
    xbar = lse - ent

    if use_pallas:
        dx = fused_rl_loss_bwd_kernel(
            logits, targets, lse, xbar, dlp, g_ent,
            block_n=block_n, block_v=block_v, interpret=_interpret())
    else:
        x = logits.astype(jnp.float32)
        p = jnp.exp(x - lse[:, None])                 # softmax, recomputed
        dx = -p * (dlp[:, None] + g_ent[:, None] * (x - xbar[:, None]))
        dx = dx.at[jnp.arange(x.shape[0]), targets].add(dlp)
        dx = dx.astype(logits.dtype)

    g_old = (-g_pl * dpl_dlp - g_ratio * ratio).astype(old_lp.dtype)
    g_ref = (g_kl * (expd - 1.0)).astype(ref_lp.dtype)
    g_adv = (-g_pl * jnp.where(sel, ratio, clip_r)).astype(adv.dtype)
    g_tgt = np.zeros(targets.shape, jax.dtypes.float0)
    return dx, g_tgt, g_old, g_ref, g_adv


_fused.defvjp(_fused_fwd, _fused_bwd)


def fused_rl_loss(logits, targets, old_logprob, ref_logprob, advantage, *,
                  clip_eps=0.2, use_pallas=False, block_n=256,
                  block_v=2048):
    """(..., V) logits + (...) per-token vectors ->
    (logprob, entropy, kl, policy_loss, ratio), each shaped like targets,
    float32. Differentiable w.r.t. logits/old/ref/advantage."""
    shape = targets.shape
    V = logits.shape[-1]
    outs = _fused(logits.reshape(-1, V), targets.reshape(-1).astype(jnp.int32),
                  old_logprob.reshape(-1), ref_logprob.reshape(-1),
                  advantage.reshape(-1), float(clip_eps), bool(use_pallas),
                  int(block_n), int(block_v))
    return tuple(o.reshape(shape) for o in outs)
