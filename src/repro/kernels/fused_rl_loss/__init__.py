from repro.kernels.fused_rl_loss.ops import fused_rl_loss
from repro.kernels.fused_rl_loss.ref import fused_rl_loss_ref

__all__ = ["fused_rl_loss", "fused_rl_loss_ref"]
