"""Fused GRPO/PPO actor hot path over large-vocab logits — Pallas kernels.

The actor update's dominant cost is touching the (B·S, V) logits, V up
to 256k. Composing ``token_logprobs`` + ``kl_penalty`` +
``clipped_policy_loss`` reads that array once forward and — through
autodiff of log-softmax — again backward, materializing a second
(B·S, V) residual in between. This module streams vocab blocks through
VMEM **once** per pass instead:

forward (``_fwd_kernel``, extends the ``grpo_logprob`` online-LSE
skeleton):

  m, l   — running max / rescaled Σ exp (online log-sum-exp)
  t      — running Σ exp(x − m)·x            (entropy)
  g      — the target token's logit          (picked up as its block goes by)

and, on the last vocab block, finishes the whole per-token epilogue in
registers: logprob ``lp = g − lse``, entropy ``ent = lse − t/l``, k3 KL
``exp(d) − d − 1`` with ``d = ref_lp − lp``, importance ratio
``exp(lp − old_lp)`` and the clipped surrogate
``−min(ratio·A, clip(ratio)·A)``.

backward (``_bwd_kernel``): no (N, V) residual is saved. With
``p = softmax(x)`` recomputed per block from the saved (N,) statistics
(``p = exp(x − lse)``) and ``x̄ = Σ p·x = lse − ent``:

  ∂lp/∂x_j  = δ_jt − p_j
  ∂ent/∂x_j = −p_j (x_j − x̄)

so every per-token output folds into two scalars — ``dlp`` (total
cotangent reaching lp) and ``g_ent`` — and

  dx_j = dlp·δ_jt − p_j (dlp + g_ent·(x_j − x̄))

which the kernel evaluates blockwise in one more single pass over the
logits. The chain-rule scalars live in ``ops.py`` (shared with the
pure-jnp route so both hit the same custom VJP).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pad_utils import (NEG_INF, pad_logits, pad_rows,
                                     pick_blocks)


def _fwd_kernel(logits_ref, target_ref, old_ref, ref_ref, adv_ref,
                lp_ref, ent_ref, kl_ref, pl_ref, ratio_ref, lse_ref,
                m_ref, l_ref, t_ref, g_ref, *,
                block_v, num_v_blocks, clip_eps):
    jv = pl.program_id(1)

    @pl.when(jv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        t_ref[...] = jnp.zeros_like(t_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    x = logits_ref[...].astype(jnp.float32)          # (BN, BV)
    tgt = target_ref[...]                            # (BN,)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, x.max(-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(x - m_new[:, None])
    l_ref[...] = alpha * l_ref[...] + p.sum(-1)
    t_ref[...] = alpha * t_ref[...] + (p * x).sum(-1)
    m_ref[...] = m_new

    v0 = jv * block_v
    local = tgt - v0
    in_block = (local >= 0) & (local < block_v)
    idx = jnp.clip(local, 0, block_v - 1)
    picked = jnp.take_along_axis(x, idx[:, None], axis=1)[:, 0]
    g_ref[...] = jnp.where(in_block, picked, g_ref[...])

    @pl.when(jv == num_v_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        lse = m_ref[...] + jnp.log(l)
        lp = g_ref[...] - lse
        ent = lse - t_ref[...] / l

        old = old_ref[...].astype(jnp.float32)
        ref = ref_ref[...].astype(jnp.float32)
        adv = adv_ref[...].astype(jnp.float32)

        ratio = jnp.exp(lp - old)
        unclipped = ratio * adv
        clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
        d = ref - lp                                  # k3 KL estimator
        kl = jnp.exp(d) - d - 1.0

        lp_ref[...] = lp
        ent_ref[...] = ent
        kl_ref[...] = kl
        pl_ref[...] = -jnp.minimum(unclipped, clipped)
        ratio_ref[...] = ratio
        lse_ref[...] = lse


def _bwd_kernel(logits_ref, target_ref, lse_ref, xbar_ref, dlp_ref,
                gent_ref, dx_ref, *, block_v):
    jv = pl.program_id(1)
    x = logits_ref[...].astype(jnp.float32)          # (BN, BV)
    p = jnp.exp(x - lse_ref[...][:, None])           # softmax, recomputed

    local = target_ref[...] - jv * block_v
    onehot = (jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
              == local[:, None]).astype(jnp.float32)

    dlp = dlp_ref[...][:, None]
    gent = gent_ref[...][:, None]
    dx = dlp * onehot - p * (dlp + gent * (x - xbar_ref[...][:, None]))
    dx_ref[...] = dx.astype(dx_ref.dtype)


@functools.partial(jax.jit, static_argnames=("clip_eps", "block_n",
                                             "block_v", "interpret"))
def fused_rl_loss_fwd_kernel(logits, targets, old_logprob, ref_logprob,
                             advantage, *, clip_eps=0.2, block_n=256,
                             block_v=2048, interpret=False):
    """One streamed pass: (N, V) logits + four (N,) vectors ->
    (lp, ent, kl, pl, ratio, lse), each (N,) float32. Any (N, V) shape:
    rows/vocab are padded to block multiples and the tail sliced off."""
    N, V = logits.shape
    bn, bv, n_pad, v_pad = pick_blocks(N, V, block_n, block_v)
    nn, nv = n_pad // bn, v_pad // bv

    lg = pad_logits(logits, n_pad, v_pad)
    tg = pad_rows(targets, n_pad)
    old = pad_rows(old_logprob, n_pad)
    ref = pad_rows(ref_logprob, n_pad)
    adv = pad_rows(advantage, n_pad)

    kernel = functools.partial(_fwd_kernel, block_v=bv, num_v_blocks=nv,
                               clip_eps=float(clip_eps))
    row = pl.BlockSpec((bn,), lambda i, j: (i,))
    outs = pl.pallas_call(
        kernel,
        grid=(nn, nv),
        in_specs=[pl.BlockSpec((bn, bv), lambda i, j: (i, j)),
                  row, row, row, row],
        out_specs=[row] * 6,
        out_shape=[jax.ShapeDtypeStruct((n_pad,), jnp.float32)] * 6,
        scratch_shapes=[pltpu.VMEM((bn,), jnp.float32)] * 4,
        interpret=interpret,
    )(lg, tg, old, ref, adv)
    return tuple(o[:N] for o in outs)


@functools.partial(jax.jit, static_argnames=("block_n", "block_v",
                                             "interpret"))
def fused_rl_loss_bwd_kernel(logits, targets, lse, xbar, dlp, g_ent, *,
                             block_n=256, block_v=2048, interpret=False):
    """Second streamed pass: dlogits from saved (N,) statistics only."""
    N, V = logits.shape
    bn, bv, n_pad, v_pad = pick_blocks(N, V, block_n, block_v)
    nn, nv = n_pad // bn, v_pad // bv

    lg = pad_logits(logits, n_pad, v_pad)
    tg = pad_rows(targets, n_pad)
    # padded rows: lse=0 would make p = exp(0-0) = 1 — harmless (their
    # dlp/g_ent are 0 and the rows are sliced off), but keep exp bounded
    ls = pad_rows(lse, n_pad)
    xb = pad_rows(xbar, n_pad)
    dl = pad_rows(dlp, n_pad)
    ge = pad_rows(g_ent, n_pad)

    kernel = functools.partial(_bwd_kernel, block_v=bv)
    row = pl.BlockSpec((bn,), lambda i, j: (i,))
    dx = pl.pallas_call(
        kernel,
        grid=(nn, nv),
        in_specs=[pl.BlockSpec((bn, bv), lambda i, j: (i, j)),
                  row, row, row, row, row],
        out_specs=pl.BlockSpec((bn, bv), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, v_pad), logits.dtype),
        interpret=interpret,
    )(lg, tg, ls, xb, dl, ge)
    return dx[:N, :V]
