"""Pad-and-mask helpers shared by the vocab-streaming kernels.

Pallas grids want block-divisible shapes; real batches rarely oblige.
The convention here:

  * rows (token axis)  — pad with zeros, slice the tail off the outputs.
    Padded rows compute garbage that is never read.
  * vocab (class axis) — pad with ``NEG_INF`` so padded logits vanish
    under exp() inside the online log-sum-exp. Safe because the first
    vocab block always holds real values, so the running max is finite
    before any padded block streams by (exp(NEG_INF - m) underflows
    to exactly 0.0, and 0.0 * NEG_INF never occurs: the kernels multiply
    p * x only where p came from real logits or is exactly zero times a
    finite rescale).

``pick_blocks`` rounds block sizes to hardware-friendly multiples
(8 sublanes, 128 lanes) capped by the padded extent.
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def _round_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def pick_blocks(n: int, v: int, block_n: int, block_v: int):
    """Return (bn, bv, n_pad, v_pad): block sizes + padded extents."""
    bn = min(block_n, _round_up(n, 8))
    bv = min(block_v, _round_up(v, 128))
    return bn, bv, _round_up(n, bn), _round_up(v, bv)


def pad_logits(x, n_pad: int, v_pad: int):
    """Pad (N, V) logits: zero rows below, NEG_INF columns to the right."""
    n, v = x.shape
    if v_pad > v:
        x = jnp.pad(x, ((0, 0), (0, v_pad - v)),
                    constant_values=jnp.asarray(NEG_INF, x.dtype))
    if n_pad > n:
        x = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    return x


def pad_rows(x, n_pad: int, fill=0):
    """Pad a per-token (N,) vector with ``fill`` up to n_pad rows."""
    n = x.shape[0]
    if n_pad > n:
        x = jnp.pad(x, (0, n_pad - n), constant_values=fill)
    return x
