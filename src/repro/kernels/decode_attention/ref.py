"""Pure-jnp oracle for decode_attention."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, valid):
    """q: (B,1,H,hd); caches: (B,S,KVH,hd); valid: (B,S)."""
    B, _, H, hd = q.shape
    KVH = k_cache.shape[2]
    if KVH != H:
        k_cache = jnp.repeat(k_cache, H // KVH, axis=2)
        v_cache = jnp.repeat(v_cache, H // KVH, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * hd ** -0.5
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w,
                      v_cache.astype(jnp.float32)).astype(q.dtype)
