"""jit'd wrapper; interpret-mode off-TPU, oracle fallback for odd shapes."""
import jax

from repro.kernels.decode_attention.decode_attention import \
    decode_attention_kernel
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention(q, k_cache, v_cache, valid, *, block_k=512):
    S = k_cache.shape[1]
    bk = min(block_k, S)
    if S % bk:
        return decode_attention_ref(q, k_cache, v_cache, valid)
    return decode_attention_kernel(q, k_cache, v_cache, valid, block_k=bk,
                                   interpret=jax.default_backend() != "tpu")
