"""Flash-decode — single-token attention against a long KV cache.

TPU adaptation: the KV cache is streamed through VMEM in BK-row blocks
along the trailing (sequential) grid dim; the per-(batch, head) partial
softmax state (m, l, acc) is carried in VMEM scratch and finalized on the
last block. A validity mask stream handles ring-buffer/partially-filled
caches. This is the decode_32k / long_500k hotspot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, num_k_blocks, scale):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (1, hd) row
    k = k_ref[0, 0].astype(jnp.float32)              # (BK, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    valid = valid_ref[0]                             # (BK,)

    s = (k @ q[0]) * scale                           # (BK,)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[0] = alpha * l_ref[0] + p.sum()
    acc_ref[...] = acc_ref[...] * alpha + (p[:, None] * v).sum(0, keepdims=True)
    m_ref[0] = m_new

    @pl.when(j == num_k_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[0], 1e-30)).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention_kernel(q, k_cache, v_cache, valid, *, block_k=512,
                            interpret=False):
    """q: (B, 1, H, hd); k/v_cache: (B, S, KVH, hd); valid: (B, S) bool.

    Returns (B, 1, H, hd)."""
    B, _, H, hd = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    n_rep = H // KVH
    block_k = min(block_k, S)
    assert S % block_k == 0
    nk = S // block_k

    qt = q.transpose(0, 2, 1, 3)                      # (B,H,1,hd)
    kt = k_cache.transpose(0, 2, 1, 3)                # (B,KVH,S,hd)
    vt = v_cache.transpose(0, 2, 1, 3)

    kernel = functools.partial(_decode_kernel, num_k_blocks=nk,
                               scale=hd ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, j, n_rep=n_rep: (b, h // n_rep, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, j, n_rep=n_rep: (b, h // n_rep, j, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, valid)
    return out.transpose(0, 2, 1, 3)
