"""Pallas TPU kernels for the RL post-training compute hotspots.

Each subpackage ships <name>.py (pl.pallas_call + BlockSpec tiling),
ops.py (jit'd public wrapper) and ref.py (pure-jnp oracle):

  flash_attention  — blockwise causal/sliding-window GQA (prefill + train)
  decode_attention — flash-decode vs long KV caches (decode_32k, long_500k)
  rglru_scan       — RG-LRU linear recurrence (recurrentgemma)
  mamba_scan       — mamba-1 selective scan (falcon-mamba)
  grpo_logprob     — fused token-logprob+entropy over 100k-256k vocab
  fused_rl_loss    — the whole GRPO/PPO actor hot path (logprob + entropy
                     + k3 KL + clipped surrogate) in one vocab pass, with
                     a hand-written VJP that recomputes softmax blockwise
                     instead of saving a (B·S, V) residual
"""
