"""Human-readable telemetry report — the per-stage table behind
``WorkflowResult.telemetry`` (paper Fig. 11 as numbers, not pixels).

``build_telemetry`` folds the run's :class:`EventLog` plus the metrics
registry into one JSON-safe dict:

* ``stages``    — one row per stage kind: worker count, busy seconds,
  samples processed, samples/s against the run wall clock.
* ``instances`` — one row per worker instance: busy % (overlap-merged)
  and wait % (blocked fetch + weight sync).
* ``staleness`` — p50/p95/max of observed weight staleness at the
  consuming train stage.
* ``metrics``   — the raw ``MetricsRegistry.snapshot()``.

``render_report`` renders the stage/instance tables as fixed-width text
for terminals and CI logs.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.obs.registry import MetricsRegistry, quantile

BOOKKEEPING_KINDS = ("wait", "weight_sync")


def build_telemetry(log, registry: Optional[MetricsRegistry],
                    wall_time_s: float, samples_trained: int,
                    staleness_seen: Optional[List[int]] = None) -> dict:
    events = log.events()
    wall = max(float(wall_time_s), 1e-9)

    by_kind: Dict[str, dict] = {}
    for e in events:
        if e.kind in BOOKKEEPING_KINDS:
            continue
        row = by_kind.setdefault(e.kind, {
            "stage": e.kind, "workers": set(), "calls": 0,
            "busy_s": 0.0, "samples": 0})
        row["workers"].add(e.instance)
        row["calls"] += 1
        row["busy_s"] += e.duration
        row["samples"] += int(e.meta.get("n", 0))

    stages = []
    for kind in sorted(by_kind):
        row = by_kind[kind]
        stages.append({
            "stage": kind,
            "workers": len(row["workers"]),
            "calls": row["calls"],
            "busy_s": round(row["busy_s"], 4),
            "samples": row["samples"],
            "samples_per_s": round(row["samples"] / wall, 2),
        })

    instances = {}
    for inst in log.instances():
        instances[inst] = {
            "busy_frac": round(log.busy_fraction(inst), 4),
            "wait_frac": round(log.wait_fraction(inst), 4),
        }

    stale = sorted(float(s) for s in (staleness_seen or []))
    staleness = {
        "count": len(stale),
        "p50": quantile(stale, 0.50) if stale else 0.0,
        "p95": quantile(stale, 0.95) if stale else 0.0,
        "max": stale[-1] if stale else 0.0,
    }

    return {
        "wall_time_s": round(wall, 4),
        "samples_trained": int(samples_trained),
        "throughput": round(samples_trained / wall, 2),
        "stages": stages,
        "instances": instances,
        "staleness": staleness,
        "metrics": registry.snapshot() if registry is not None else {},
    }


def render_report(telemetry: dict) -> str:
    """Fixed-width per-stage / per-instance tables from ``build_telemetry``
    output (or ``WorkflowResult.telemetry``)."""
    lines = [
        f"run: wall {telemetry['wall_time_s']:.2f}s · "
        f"{telemetry['samples_trained']} samples · "
        f"{telemetry['throughput']:.1f} samples/s",
        "",
        f"{'stage':>16s} {'workers':>7s} {'calls':>6s} {'busy_s':>8s} "
        f"{'samples':>8s} {'samples/s':>10s}",
    ]
    for row in telemetry.get("stages", []):
        lines.append(
            f"{row['stage']:>16s} {row['workers']:>7d} {row['calls']:>6d} "
            f"{row['busy_s']:>8.2f} {row['samples']:>8d} "
            f"{row['samples_per_s']:>10.1f}")
    lines += ["", f"{'instance':>16s} {'busy %':>7s} {'wait %':>7s}"]
    for inst, row in sorted(telemetry.get("instances", {}).items()):
        lines.append(f"{inst:>16s} {100 * row['busy_frac']:>6.1f}% "
                     f"{100 * row['wait_frac']:>6.1f}%")
    st = telemetry.get("staleness", {})
    if st.get("count"):
        lines += ["", f"staleness: p50 {st['p50']:.1f} · "
                      f"p95 {st['p95']:.1f} · max {st['max']:.0f} "
                      f"({st['count']} samples)"]
    rollout = _rollout_summary(telemetry.get("metrics", {}))
    if rollout:
        lines += ["", rollout]
    superv = _supervision_summary(telemetry.get("metrics", {}))
    if superv:
        lines += ["", superv]
    recov = _recovery_summary(telemetry.get("metrics", {}))
    if recov:
        lines += ["", recov]
    return "\n".join(lines)


def _metric_values(metrics: dict, name: str) -> List[dict]:
    return metrics.get(name, {}).get("values", [])


def _rollout_summary(metrics: dict) -> str:
    """One-line continuous-batching rollout summary: slot occupancy,
    admissions, KV pages, and the prefill/decode time split."""
    occ = _metric_values(metrics, "rollout_slot_occupancy")
    if not occ:
        return ""
    adm = sum(v["value"] for v in
              _metric_values(metrics, "rollout_admissions_total"))
    pages = sum(v["value"] for v in
                _metric_values(metrics, "rollout_kv_pages_in_use"))
    pre = _metric_values(metrics, "rollout_prefill_seconds")
    dec = _metric_values(metrics, "rollout_decode_step_seconds")
    pre_s = sum(v.get("sum", 0.0) for v in pre)
    dec_s = sum(v.get("sum", 0.0) for v in dec)
    tot = pre_s + dec_s
    split = (f"prefill {100 * pre_s / tot:.0f}% / "
             f"decode {100 * dec_s / tot:.0f}%") if tot > 0 else "idle"
    return (f"rollout: occupancy {occ[-1]['value']:.2f} · "
            f"{int(adm)} admissions · {int(pages)} kv pages · {split} "
            f"({tot:.2f}s)")


def _supervision_summary(metrics: dict) -> str:
    """One-line fault-tolerance summary: replica restarts, in-place stage
    retries, rows requeued after consumer deaths, injected faults."""
    restarts = sum(v["value"] for v in
                   _metric_values(metrics, "replica_restarts_total"))
    retries = sum(v["value"] for v in
                  _metric_values(metrics, "stage_retries_total"))
    requeued = sum(v["value"] for v in
                   _metric_values(metrics, "rows_requeued_total"))
    injected = sum(v["value"] for v in
                   _metric_values(metrics, "faults_injected_total"))
    if not (restarts or retries or requeued or injected):
        return ""
    line = (f"supervision: {int(restarts)} replica restarts · "
            f"{int(retries)} stage retries · "
            f"{int(requeued)} rows requeued")
    if injected:
        line += f" · {int(injected)} faults injected"
    return line


def _recovery_summary(metrics: dict) -> str:
    """One-line durability summary: run snapshots committed (bytes +
    write wall time), warm trainer restarts, duplicate rows dropped."""
    writes = _metric_values(metrics, "checkpoint_write_seconds")
    n_snaps = sum(v.get("count", 0) for v in writes)
    if not n_snaps:
        return ""
    w_s = sum(v.get("sum", 0.0) for v in writes)
    mb = sum(v["value"] for v in
             _metric_values(metrics, "checkpoint_bytes_total")) / 1e6
    restarts = sum(v["value"] for v in
                   _metric_values(metrics, "trainer_restarts_total"))
    dups = sum(v["value"] for v in
               _metric_values(metrics, "rows_dropped_duplicate_total"))
    line = (f"recovery: {int(n_snaps)} snapshots · {mb:.2f} MB · "
            f"{w_s:.2f}s write time · {int(restarts)} trainer restarts")
    if dups:
        line += f" · {int(dups)} duplicate rows dropped"
    return line
