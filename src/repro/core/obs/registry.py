"""Metrics registry — labeled counters, gauges and histograms.

The unified telemetry layer for the streaming RL dataflow: every hot
layer (TransferQueue controllers, StageRunner workers, the weight-sync
path) records into one :class:`MetricsRegistry`. The registry is
deliberately tiny and dependency-free (stdlib only) so the control plane
can afford to update it inside its scheduling locks:

* :class:`Counter`   — monotonically increasing totals
  (``tq_rows_consumed_total``, ``stage_tokens_total``, ...).
* :class:`Gauge`     — last-write-wins instantaneous values
  (``tq_ready_depth``).
* :class:`Histogram` — value distributions with p50/p95/p99 summaries
  (``stage_batch_seconds``, ``train_staleness``).

Every metric family is labeled: ``counter.inc(3, stage="generate")``
keeps one series per label set. Hot paths pre-bind a label set once with
``metric.labels(stage="generate")`` and call ``.inc()``/``.observe()``
on the bound handle, avoiding per-call label sorting.

A process-global default registry backs everything that does not pass an
explicit registry (``get_registry()``); tests isolate themselves with
``with scoped() as reg: ...`` which swaps the default in and out.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional, Tuple


def _label_key(labels: dict) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted(labels.items()))


def quantile(xs_sorted: List[float], q: float) -> float:
    """Linearly interpolated quantile of an ascending-sorted list."""
    if not xs_sorted:
        return float("nan")
    if len(xs_sorted) == 1:
        return float(xs_sorted[0])
    pos = q * (len(xs_sorted) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(xs_sorted) - 1)
    frac = pos - lo
    return float(xs_sorted[lo] * (1.0 - frac) + xs_sorted[hi] * frac)


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._series: Dict[Tuple, object] = {}

    def label_sets(self) -> List[dict]:
        with self._lock:
            return [dict(k) for k in self._series]


class _BoundCounter:
    __slots__ = ("_metric", "_key")

    def __init__(self, metric: "Counter", key: Tuple):
        self._metric = metric
        self._key = key

    def inc(self, value: float = 1.0) -> None:
        m = self._metric
        with m._lock:
            m._series[self._key] = m._series.get(self._key, 0.0) + value


class Counter(_Metric):
    kind = "counter"

    def labels(self, **labels) -> _BoundCounter:
        return _BoundCounter(self, _label_key(labels))

    def inc(self, value: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + value

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._series.get(_label_key(labels), 0.0))

    def total(self) -> float:
        with self._lock:
            return float(sum(self._series.values()))

    def snapshot(self) -> List[dict]:
        with self._lock:
            items = list(self._series.items())
        return [{"labels": dict(k), "value": float(v)} for k, v in items]


class _BoundGauge:
    __slots__ = ("_metric", "_key")

    def __init__(self, metric: "Gauge", key: Tuple):
        self._metric = metric
        self._key = key

    def set(self, value: float) -> None:
        m = self._metric
        with m._lock:
            m._series[self._key] = float(value)

    def inc(self, value: float = 1.0) -> None:
        m = self._metric
        with m._lock:
            m._series[self._key] = m._series.get(self._key, 0.0) + value


class Gauge(_Metric):
    kind = "gauge"

    def labels(self, **labels) -> _BoundGauge:
        return _BoundGauge(self, _label_key(labels))

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._series[_label_key(labels)] = float(value)

    def inc(self, value: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + value

    def dec(self, value: float = 1.0, **labels) -> None:
        self.inc(-value, **labels)

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._series.get(_label_key(labels), 0.0))

    def snapshot(self) -> List[dict]:
        with self._lock:
            items = list(self._series.items())
        return [{"labels": dict(k), "value": float(v)} for k, v in items]


class _HistSeries:
    __slots__ = ("count", "total", "mn", "mx", "samples")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.mn = float("inf")
        self.mx = float("-inf")
        self.samples: List[float] = []


class _BoundHistogram:
    __slots__ = ("_metric", "_key")

    def __init__(self, metric: "Histogram", key: Tuple):
        self._metric = metric
        self._key = key

    def observe(self, value: float) -> None:
        self._metric._observe(self._key, value)


class Histogram(_Metric):
    """Distribution summary. ``count``/``sum``/``min``/``max`` are exact;
    quantiles come from a bounded ring of the most recent ``max_samples``
    observations (older samples are overwritten — a run-scoped summary,
    not an archival reservoir)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "", max_samples: int = 65536):
        super().__init__(name, help)
        self.max_samples = max_samples

    def labels(self, **labels) -> _BoundHistogram:
        return _BoundHistogram(self, _label_key(labels))

    def _observe(self, key: Tuple, value: float) -> None:
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = _HistSeries()
            v = float(value)
            s.total += v
            s.mn = min(s.mn, v)
            s.mx = max(s.mx, v)
            if len(s.samples) < self.max_samples:
                s.samples.append(v)
            else:
                s.samples[s.count % self.max_samples] = v
            s.count += 1

    def observe(self, value: float, **labels) -> None:
        self._observe(_label_key(labels), value)

    @staticmethod
    def _summary(s: _HistSeries) -> dict:
        xs = sorted(s.samples)
        return {
            "count": s.count,
            "sum": s.total,
            "min": s.mn if s.count else float("nan"),
            "max": s.mx if s.count else float("nan"),
            "mean": s.total / s.count if s.count else float("nan"),
            "p50": quantile(xs, 0.50),
            "p95": quantile(xs, 0.95),
            "p99": quantile(xs, 0.99),
        }

    def summary(self, **labels) -> dict:
        with self._lock:
            s = self._series.get(_label_key(labels))
            if s is None:
                return self._summary(_HistSeries())
            return self._summary(s)

    def snapshot(self) -> List[dict]:
        with self._lock:
            items = [(k, self._summary(s)) for k, s in self._series.items()]
        return [{"labels": dict(k), **summ} for k, summ in items]


class MetricsRegistry:
    """Thread-safe registry of named metric families. ``counter()`` /
    ``gauge()`` / ``histogram()`` are get-or-create: the same name always
    returns the same family (and raises TypeError on a kind mismatch), so
    instrumented layers never need to coordinate creation order."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, cls, name: str, help: str, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, **kw)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  max_samples: int = 65536) -> Histogram:
        return self._get_or_create(Histogram, name, help,
                                   max_samples=max_samples)

    def get(self, name: str) -> Optional[_Metric]:
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> Dict[str, dict]:
        """{metric_name: {"type", "help", "values": [...]}} — JSON-safe."""
        with self._lock:
            metrics = list(self._metrics.values())
        return {m.name: {"type": m.kind, "help": m.help,
                         "values": m.snapshot()}
                for m in metrics}

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()


# -- process-global default -------------------------------------------------

_default_registry = MetricsRegistry()
_default_lock = threading.Lock()


def get_registry() -> MetricsRegistry:
    """The process-global default registry (what instrumented layers use
    when not handed an explicit registry)."""
    return _default_registry


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Replace the process-global default; returns the previous one."""
    global _default_registry
    with _default_lock:
        prev = _default_registry
        _default_registry = registry
        return prev


@contextmanager
def scoped(registry: Optional[MetricsRegistry] = None
           ) -> Iterator[MetricsRegistry]:
    """Swap a fresh (or given) registry in as the process default for the
    duration of the block — the test-isolation helper."""
    reg = registry if registry is not None else MetricsRegistry()
    prev = set_registry(reg)
    try:
        yield reg
    finally:
        set_registry(prev)
