"""Unified telemetry layer: metrics registry, periodic JSONL sampler and
the per-stage report. Stdlib-only — safe to import from the control
plane's hot paths."""
from repro.core.obs.registry import (Counter, Gauge, Histogram,
                                     MetricsRegistry, get_registry,
                                     quantile, scoped, set_registry)
from repro.core.obs.report import build_telemetry, render_report
from repro.core.obs.sampler import MetricsSampler

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "MetricsSampler", "build_telemetry", "get_registry", "quantile",
           "render_report", "scoped", "set_registry"]
