"""Periodic JSONL metrics emitter.

A daemon thread that appends ``MetricsRegistry.snapshot()`` to a file as
one JSON object per line at a fixed interval — the machine-readable
timeline that pairs with the Chrome trace (spans) and the final report
(aggregates). :class:`StageRunner` starts one when
``WorkflowConfig.metrics_jsonl`` is set and stops it (with a final
flush sample) when the run ends.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Optional

from repro.core.obs.registry import MetricsRegistry


class MetricsSampler:
    """Appends one ``{"t": ..., "elapsed_s": ..., "metrics": ...}`` line
    per ``interval_s`` to ``path``. Thread-safe, idempotent stop."""

    def __init__(self, registry: MetricsRegistry, path: str,
                 interval_s: float = 0.25):
        self.registry = registry
        self.path = path
        self.interval_s = max(0.01, float(interval_s))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._t0 = time.monotonic()
        self._write_lock = threading.Lock()

    def _emit(self, fh) -> None:
        line = json.dumps({
            "t": time.time(),
            "elapsed_s": round(time.monotonic() - self._t0, 6),
            "metrics": self.registry.snapshot(),
        })
        with self._write_lock:
            fh.write(line + "\n")
            fh.flush()

    def _loop(self) -> None:
        with open(self.path, "a") as fh:
            while not self._stop.wait(self.interval_s):
                self._emit(fh)
            self._emit(fh)   # final sample so short runs never emit zero

    def start(self) -> "MetricsSampler":
        self._t0 = time.monotonic()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="metrics-sampler")
        self._thread.start()
        return self

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None
