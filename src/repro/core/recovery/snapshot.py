"""Durable run-level snapshots — checkpoint-based trainer recovery.

AsyncFlow (§3.3–§4) treats a long post-training run as a restartable
distributed job: any component — including the trainer — may die and
rejoin without losing or duplicating trajectories. The engine-level
checkpoint (`training/checkpoint.py`) only captures a param/optimizer
pytree; a *run* snapshot must also capture the streaming state around
it, so :class:`RunCheckpointer` bundles per snapshot:

* every train-side engine state (actor, critic) via the crash-atomic
  pytree checkpointer,
* the published weight version, staleness counters and step metrics,
* the RNG/sampling counter bases (rollout group id + continuous-batching
  uid base) so cold-resumed generation re-primes deterministically,
* the dataset/prompt-feed cursor (the feed step — `PromptDataset` is
  deterministic per step), and
* the TransferQueue durable cursor: the global uid watermark, per-task
  consumed counts and the in-flight leases, plus the acked-uid
  watermark the duplicate guard checks on restart.

Snapshots are written with the same torn-write discipline as the
engine checkpointer: everything lands in a ``.tmp-*`` directory, is
fsynced, and is renamed to ``snapshot-<step>`` in one step; a ``LATEST``
pointer is then atomically replaced and retention prunes all but the
newest ``keep_last``. ``resolve("auto")`` validates before trusting:
a torn temp directory or a corrupt snapshot (e.g. a SIGKILL mid-write)
is skipped and the previous intact snapshot wins.
"""
from __future__ import annotations

import json
import os
import shutil
import time
import uuid
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.obs import get_registry
from repro.training.checkpoint import (fsync_path, restore_checkpoint,
                                       save_checkpoint)

__all__ = ["RunCheckpointer"]

SCHEMA = "asyncflow-run-snapshot/v1"
LATEST = "LATEST"


def _json_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"not JSON-serializable: {type(o)}")


def _dir_bytes(path: str) -> int:
    total = 0
    for root, _, files in os.walk(path):
        for f in files:
            try:
                total += os.path.getsize(os.path.join(root, f))
            except OSError:
                pass
    return total


class RunCheckpointer:
    """Atomic, versioned run snapshots with a LATEST pointer and
    keep-last-k retention.

    ``save`` commits one snapshot; ``resolve`` finds the newest *intact*
    snapshot (or validates an explicit path); ``load``/``load_engine``
    read the run state and nested engine checkpoints back.
    """

    def __init__(self, directory: str, *, keep_last: int = 3,
                 metrics=None):
        self.dir = os.path.normpath(directory)
        self.keep_last = max(1, int(keep_last))
        os.makedirs(self.dir, exist_ok=True)
        m = metrics if metrics is not None else get_registry()
        self._h_write = m.histogram(
            "checkpoint_write_seconds",
            "wall seconds per committed run snapshot")
        self._c_bytes = m.counter(
            "checkpoint_bytes_total",
            "bytes durably written across run snapshots")

    # -- paths ----------------------------------------------------------

    def snapshot_path(self, step: int) -> str:
        return os.path.join(self.dir, f"snapshot-{int(step):08d}")

    def _latest_path(self) -> str:
        return os.path.join(self.dir, LATEST)

    # -- write ----------------------------------------------------------

    def save(self, step: int, run_state: dict,
             engine_states: Optional[Dict[str, Any]] = None) -> str:
        """Commit one snapshot: engine pytrees + run.json, atomically.
        Re-saving an existing step (a warm-restarted trainer redoing
        work) replaces the old snapshot whole, never in place."""
        t0 = time.monotonic()
        engine_states = engine_states or {}
        final = self.snapshot_path(step)
        nonce = uuid.uuid4().hex[:8]
        tmp = os.path.join(self.dir,
                           f".tmp-snapshot-{int(step):08d}-{nonce}")
        os.makedirs(tmp)
        try:
            for key, state in engine_states.items():
                save_checkpoint(os.path.join(tmp, key), state, step=step)
            doc = {"schema": SCHEMA, "step": int(step),
                   "engines": sorted(engine_states), **run_state}
            run_path = os.path.join(tmp, "run.json")
            with open(run_path, "w") as f:
                json.dump(doc, f, default=_json_default)
                f.flush()
                os.fsync(f.fileno())
            fsync_path(tmp)
            if os.path.isdir(final):
                old = f"{final}.old-{nonce}"
                os.rename(final, old)
                os.rename(tmp, final)
                shutil.rmtree(old, ignore_errors=True)
            else:
                os.rename(tmp, final)
            fsync_path(self.dir)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._write_latest(os.path.basename(final))
        self._prune()
        self._h_write.observe(time.monotonic() - t0)
        self._c_bytes.inc(_dir_bytes(final))
        return final

    def _write_latest(self, name: str) -> None:
        tmp = self._latest_path() + f".tmp-{uuid.uuid4().hex[:8]}"
        with open(tmp, "w") as f:
            f.write(name + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._latest_path())
        fsync_path(self.dir)

    def _prune(self) -> None:
        snaps = self.list_snapshots()
        for name in snaps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, name),
                          ignore_errors=True)
        # sweep torn temp dirs from crashed writers (never load targets)
        for name in os.listdir(self.dir):
            if name.startswith(".tmp-snapshot-") or ".old-" in name:
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    # -- discovery / validation -----------------------------------------

    def list_snapshots(self) -> List[str]:
        """Committed snapshot names, oldest first (temp dirs excluded)."""
        try:
            names = os.listdir(self.dir)
        except FileNotFoundError:
            return []
        return sorted(n for n in names
                      if n.startswith("snapshot-") and ".old-" not in n
                      and os.path.isdir(os.path.join(self.dir, n)))

    def _valid(self, path: str) -> bool:
        """A snapshot is intact iff run.json parses and every nested
        engine checkpoint loads (npz central directory + meta)."""
        try:
            with open(os.path.join(path, "run.json")) as f:
                doc = json.load(f)
            if doc.get("schema") != SCHEMA:
                return False
            for key in doc.get("engines", []):
                eng_dir = os.path.join(path, key)
                with open(os.path.join(eng_dir, "meta.json")) as f:
                    json.load(f)
                with np.load(os.path.join(eng_dir, "arrays.npz")) as z:
                    list(z.files)
            return True
        except Exception:
            return False

    def resolve(self, resume: str = "auto") -> Optional[str]:
        """Path of the snapshot to restore from, or None.

        ``"auto"`` tries the LATEST pointer first, then scans committed
        snapshots newest-first — a snapshot torn by a SIGKILL mid-write
        (or a dangling pointer) is skipped and the previous intact one
        wins. An explicit path is validated and returned as-is."""
        if resume and resume != "auto":
            path = os.path.normpath(resume)
            if not self._valid(path):
                raise FileNotFoundError(
                    f"no intact run snapshot at {path!r}")
            return path
        try:
            with open(self._latest_path()) as f:
                name = f.read().strip()
            cand = os.path.join(self.dir, name)
            if name and self._valid(cand):
                return cand
        except OSError:
            pass
        for name in reversed(self.list_snapshots()):
            cand = os.path.join(self.dir, name)
            if self._valid(cand):
                return cand
        return None

    # -- read -----------------------------------------------------------

    @staticmethod
    def load(path: str) -> dict:
        with open(os.path.join(path, "run.json")) as f:
            return json.load(f)

    @staticmethod
    def load_engine(path: str, key: str, like: Any):
        """Restore one nested engine checkpoint; returns (tree, step)."""
        return restore_checkpoint(os.path.join(path, key), like)
