"""Recovery & durability: atomic versioned run snapshots (engine state +
streaming cursors + TransferQueue watermarks) with LATEST pointer,
keep-last-k retention and torn-snapshot fallback — the substrate for
warm trainer restarts and cold ``Trainer.fit(resume=...)``."""
from repro.core.recovery.snapshot import RunCheckpointer

__all__ = ["RunCheckpointer"]
