from repro.core.supervision import (FaultConfig, FaultInjector, ReplicaCrash,
                                    ReplicaSupervisor, RetryableError,
                                    WeightSyncTimeout)
from repro.core.workflow.async_engine import AsyncRLRunner
from repro.core.workflow.events import Event, EventLog
from repro.core.workflow.stage_graph import (StageGraph, StageRunner,
                                             StageSpec, WorkflowConfig,
                                             WorkflowResult, build_dataflow,
                                             register_dataflow)
from repro.core.workflow.weight_sync import (BroadcastWeightChannel,
                                             StaggeredUpdateGroup,
                                             VersionedWeights, WeightChannel,
                                             WeightReceiver, WeightSender)

__all__ = ["AsyncRLRunner", "BroadcastWeightChannel", "Event", "EventLog",
           "FaultConfig", "FaultInjector", "ReplicaCrash",
           "ReplicaSupervisor", "RetryableError", "StageGraph", "StageSpec",
           "StageRunner", "StaggeredUpdateGroup", "VersionedWeights",
           "WeightChannel", "WeightReceiver", "WeightSender",
           "WeightSyncTimeout", "WorkflowConfig", "WorkflowResult",
           "build_dataflow", "register_dataflow"]
