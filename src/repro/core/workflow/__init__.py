from repro.core.workflow.async_engine import AsyncRLRunner
from repro.core.workflow.events import Event, EventLog
from repro.core.workflow.stage_graph import (StageGraph, StageRunner,
                                             StageSpec, WorkflowConfig,
                                             WorkflowResult, build_dataflow,
                                             register_dataflow)
from repro.core.workflow.weight_sync import (StaggeredUpdateGroup,
                                             VersionedWeights, WeightChannel,
                                             WeightReceiver, WeightSender)

__all__ = ["AsyncRLRunner", "WorkflowConfig", "WorkflowResult", "EventLog",
           "Event", "WeightChannel", "WeightSender", "WeightReceiver",
           "StaggeredUpdateGroup", "VersionedWeights", "StageGraph",
           "StageSpec", "StageRunner", "register_dataflow",
           "build_dataflow"]
