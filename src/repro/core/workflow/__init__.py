from repro.core.workflow.async_engine import (AsyncRLRunner, WorkflowConfig,
                                              WorkflowResult)
from repro.core.workflow.events import Event, EventLog
from repro.core.workflow.weight_sync import (StaggeredUpdateGroup,
                                             VersionedWeights, WeightChannel,
                                             WeightReceiver, WeightSender)

__all__ = ["AsyncRLRunner", "WorkflowConfig", "WorkflowResult", "EventLog",
           "Event", "WeightChannel", "WeightSender", "WeightReceiver",
           "StaggeredUpdateGroup", "VersionedWeights"]
