"""Parameter-update module — WeightSender / WeightReceiver (paper §4.2.3)
and the delayed parameter update mechanism (§4.2.2).

Two modes, mirroring the paper:

* ``sync``  — rollout blocks while weights transfer (models the
  high-bandwidth HCCL/ICI device-to-device path).
* ``async`` — the training engine offloads weights to host buffers and a
  background thread ships them over the "host network" (here: an
  in-process channel with optional simulated bandwidth); rollout keeps
  generating on the old weights and swaps at the generation-iteration
  boundary, paying only the H2D load (delayed parameter update).

Sub-step asynchrony (§4.2.2 / Fig. 8d, the paper's future work): with
``staggered=True``, receivers for different rollout instances are updated
sequentially so part of each global batch is produced by the newest
weights — implemented here as a beyond-paper feature.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.obs import get_registry
from repro.core.supervision.errors import WeightSyncTimeout


@dataclass
class VersionedWeights:
    version: int
    host_params: Any  # pytree of np.ndarray (host memory staging buffer)


class WeightChannel:
    """In-process stand-in for the host network between clusters.

    ``bandwidth_gbps`` > 0 adds a transfer delay proportional to payload
    size — used by the simulator-calibrated benchmarks.
    """

    def __init__(self, bandwidth_gbps: float = 0.0, metrics=None):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._latest: Optional[VersionedWeights] = None
        self.bandwidth_gbps = bandwidth_gbps
        self.bytes_sent = 0
        m = metrics if metrics is not None else get_registry()
        self._m_bytes = m.counter(
            "weight_bytes_published_total",
            "host-buffer bytes offered to the weight channel")

    def offer(self, vw: VersionedWeights) -> None:
        nbytes = sum(getattr(a, "nbytes", 0)
                     for a in jax.tree.leaves(vw.host_params))
        self._m_bytes.inc(nbytes)
        if self.bandwidth_gbps > 0:
            time.sleep(nbytes / (self.bandwidth_gbps * 1e9 / 8))
            self.bytes_sent += nbytes
        with self._cv:
            if self._latest is None or vw.version > self._latest.version:
                self._latest = vw
            self._cv.notify_all()

    def peek(self) -> Optional[VersionedWeights]:
        with self._lock:
            return self._latest

    def latest_version(self) -> int:
        with self._lock:
            return self._latest.version if self._latest is not None else -1

    def wait_for(self, version: int, timeout: Optional[float] = None,
                 strict: bool = False) -> Optional[VersionedWeights]:
        """Block until a snapshot with ``>= version`` is staged. On
        timeout: returns None, or with ``strict=True`` raises
        :class:`WeightSyncTimeout` naming the version waited for and the
        newest version actually seen — a timeout is never mistaken for a
        successful no-op."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while self._latest is None or self._latest.version < version:
                rem = None if deadline is None else deadline - time.monotonic()
                if rem is not None and rem <= 0:
                    if strict:
                        latest = self._latest.version \
                            if self._latest is not None else -1
                        raise WeightSyncTimeout(version, latest,
                                                timeout_s=timeout or 0.0)
                    return None
                self._cv.wait(timeout=rem if rem is not None else 0.1)
            return self._latest


class BroadcastWeightChannel(WeightChannel):
    """One-to-many weight broadcast with per-replica swap acknowledgment.

    The trainer publishes ONE versioned host snapshot per step; every
    subscribed replica reads the *same* staging buffer (the pytree is
    shared by reference — zero extra host copies per replica, and
    ``weight_bytes_published_total`` counts the payload once regardless
    of fleet size). Each receiver acks the version it swapped in, so the
    supervisor and the staleness gate can see exactly which replicas lag
    during recovery: a freshly respawned replica subscribes at its
    hand-off version and catches up on its first swap.
    """

    def __init__(self, bandwidth_gbps: float = 0.0, metrics=None):
        super().__init__(bandwidth_gbps, metrics=metrics)
        self._acked: Dict[int, int] = {}       # replica id -> acked version
        m = metrics if metrics is not None else get_registry()
        self._h_broadcast = m.histogram(
            "weight_broadcast_seconds",
            "one-to-many publish latency (one snapshot for N receivers)")

    # -- subscription registry --------------------------------------------

    def subscribe(self, replica_id: int, version: int = 0) -> None:
        with self._lock:
            self._acked[replica_id] = version

    def unsubscribe(self, replica_id: int) -> None:
        with self._lock:
            self._acked.pop(replica_id, None)

    def ack(self, replica_id: int, version: int) -> None:
        with self._lock:
            if replica_id in self._acked:
                self._acked[replica_id] = max(self._acked[replica_id],
                                              version)

    def acked_versions(self) -> Dict[int, int]:
        with self._lock:
            return dict(self._acked)

    def min_acked(self) -> int:
        """Oldest version any live replica is still generating with —
        the fleet-wide staleness floor during recovery."""
        with self._lock:
            return min(self._acked.values()) if self._acked else -1

    def num_subscribers(self) -> int:
        with self._lock:
            return len(self._acked)

    def offer(self, vw: VersionedWeights) -> None:
        t0 = time.monotonic()
        super().offer(vw)
        self._h_broadcast.observe(time.monotonic() - t0)


class WeightSender:
    """Training-cluster side. ``publish`` is non-blocking in async mode:
    device→host offload + channel send happen on a background thread,
    overlapping with the next training step (§4.2.3)."""

    def __init__(self, channel: WeightChannel, mode: str = "async",
                 metrics=None):
        assert mode in ("sync", "async")
        self.channel = channel
        self.mode = mode
        self._pending: Optional[threading.Thread] = None
        m = metrics if metrics is not None else get_registry()
        self._h_sync = m.histogram(
            "weight_sync_seconds",
            "weight publish (D2H + channel) / swap (H2D) durations")

    def publish(self, params, version: int) -> None:
        def _send():
            t0 = time.monotonic()
            host = jax.tree.map(lambda a: np.asarray(a), params)
            self.channel.offer(VersionedWeights(version, host))
            self._h_sync.observe(time.monotonic() - t0, role="publish")

        if self.mode == "sync":
            _send()
        else:
            if self._pending is not None:
                self._pending.join()
            self._pending = threading.Thread(target=_send, daemon=True)
            self._pending.start()

    def flush(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None


class WeightReceiver:
    """Inference-cluster side. Keeps the live device params plus the staged
    host buffer; ``maybe_swap()`` is called at generation-iteration
    boundaries and pays only H2D (delayed parameter update, §4.2.2)."""

    def __init__(self, channel: WeightChannel, init_params, version: int = 0,
                 to_device: Optional[Callable] = None, metrics=None,
                 replica_id: Optional[int] = None):
        self.channel = channel
        self.params = init_params
        self.version = version
        self.replica_id = replica_id
        self._to_device = to_device or (lambda tree: jax.tree.map(
            jax.numpy.asarray, tree))
        # broadcast channels track per-replica swap acknowledgment
        if replica_id is not None and hasattr(channel, "subscribe"):
            channel.subscribe(replica_id, version)
        m = metrics if metrics is not None else get_registry()
        self._h_sync = m.histogram(
            "weight_sync_seconds",
            "weight publish (D2H + channel) / swap (H2D) durations")
        self._m_skipped = m.counter(
            "weight_versions_skipped_total",
            "published versions never loaded by a receiver (delayed "
            "parameter update jumping straight to the newest)")

    def staged_version(self) -> int:
        vw = self.channel.peek()
        return vw.version if vw else self.version

    def _swap(self, vw: VersionedWeights) -> None:
        t0 = time.monotonic()
        self.params = self._to_device(vw.host_params)
        skipped = vw.version - self.version - 1
        if skipped > 0:
            self._m_skipped.inc(skipped)
        self.version = vw.version
        self._h_sync.observe(time.monotonic() - t0, role="swap")
        if self.replica_id is not None and hasattr(self.channel, "ack"):
            self.channel.ack(self.replica_id, vw.version)

    def maybe_swap(self) -> bool:
        """Swap in the newest staged weights if any. Returns True if swapped."""
        vw = self.channel.peek()
        if vw is not None and vw.version > self.version:
            self._swap(vw)
            return True
        return False

    def wait_and_swap(self, version: int, timeout: Optional[float] = None,
                      strict: bool = True) -> bool:
        """Block until ``>= version`` is staged, then swap. On timeout
        raises :class:`WeightSyncTimeout` (naming the version waited for
        and the newest one seen); ``strict=False`` restores the legacy
        return-False behavior for callers that poll."""
        vw = self.channel.wait_for(version, timeout, strict=strict)
        if vw is None:
            return False
        self._swap(vw)
        return True


class StaggeredUpdateGroup:
    """Sub-step asynchrony (Fig. 8d): rollout instances update one at a
    time so the fleet keeps serving while each instance reloads."""

    def __init__(self, receivers: List[WeightReceiver]):
        self.receivers = receivers
        self._lock = threading.Lock()
        self._updating: Optional[int] = None

    def try_begin_update(self, idx: int) -> bool:
        with self._lock:
            if self._updating is None:
                self._updating = idx
                return True
            return False

    def end_update(self, idx: int) -> None:
        with self._lock:
            if self._updating == idx:
                self._updating = None
