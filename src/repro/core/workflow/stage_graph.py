"""Streaming stage-graph: composable multi-task RL dataflows (paper §3.3, §4.1).

The paper's central architectural claim is that per-task TransferQueue
controllers over a shared data plane let *arbitrary* RL dataflows
(rollout, ref_inference, reward, critic/actor update, ...) stream and
overlap automatically. This module is that claim as a subsystem:

* :class:`StageSpec` — one named RL task: the columns it consumes, the
  columns it writes, and the engine verb (``RLAdapter``) that does the
  work.
* :class:`StageGraph` — a validated DAG of stages over a single shared
  column namespace. Topology checks (missing producers, duplicate
  producers, cycles) run before anything is scheduled.
* :class:`StageRunner` — compiles a graph onto ONE shared
  :class:`TransferQueue` (one controller per stage, §3.3) and spawns
  producer/consumer worker threads per stage. Rows flow column-by-column:
  a stage's controller schedules a row the instant its required columns
  are all present, so every intermediate task streams as its own pipeline
  stage — no global-batch barriers anywhere between source and sink.

Stage verbs return a plain dict with any of:

* ``rows``     — new sample rows to append (dict column -> value); used by
  the generate stage to fan a prompt out into G experience rows.
* ``requeue``  — continuation items fed back into the source column
  (partial rollout, §4.2.1).
* ``updates``  — {column: [values]} written back onto the consumed rows.
* ``writes``   — [(row_idx, column, value)] cross-row writes (e.g. GRPO
  group advantages that complete on a later micro-batch).

Workflow modes (baseline / streaming / async), the staleness gate,
delayed parameter update and the per-mode prompt release schedule are
owned by the runner, so every dataflow — built-in or user-registered via
:func:`register_dataflow` — inherits the paper's §4.2 machinery.
"""
from __future__ import annotations

import inspect
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.obs import (MetricsRegistry, MetricsSampler, build_telemetry,
                            get_registry)
from repro.core.supervision import (FaultConfig, FaultInjector, ReplicaCrash,
                                    ReplicaSupervisor, RetryPolicy,
                                    call_with_retry)
from repro.core.transfer_queue import TransferQueue
from repro.core.workflow.events import EventLog
from repro.core.workflow.weight_sync import (BroadcastWeightChannel,
                                             StaggeredUpdateGroup,
                                             WeightReceiver, WeightSender)


@dataclass
class WorkflowConfig:
    mode: str = "async"               # baseline | streaming | async
    num_rollout_workers: int = 2
    rollout_batch: int = 2            # prompts per generate() call
    train_micro_batch: int = 4        # samples per trainer fetch
    prompts_per_step: int = 4         # prompts consumed per training step
    group_size: int = 4               # G responses per prompt (GRPO)
    num_steps: int = 8
    staleness: int = 1
    staggered: bool = False           # sub-step async (Fig. 8d)
    num_storage_units: int = 2
    policy: Any = "fifo"           # str, or {task: str} for per-stage policy
    channel_bandwidth_gbps: float = 0.0
    extra_columns: tuple = ()      # e.g. ("ref_logprob",) for GRPO+KL
    metrics_jsonl: str = ""        # JSONL metrics-snapshot path ("" = off)
    metrics_interval_s: float = 0.25
    auto_size_workers: bool = False  # planner-size stages with num_workers=0
    elastic_interval_s: float = 0.0  # >0: live rebalance monitor cadence (s)
    max_stage_workers: int = 8       # auto-size / elastic pool cap
    # -- supervision & fault tolerance (generator fleet) -----------------
    supervise: bool = True           # heartbeats + crash respawn + requeue
    max_replica_restarts: int = 8    # fleet-wide respawn budget
    heartbeat_timeout_s: float = 10.0  # stale replica declared dead (hung)
    max_stage_retries: int = 2       # extra attempts for RetryableError
    retry_backoff_s: float = 0.05    # base of exp backoff (+ determ. jitter)
    faults: Optional[FaultConfig] = None  # deterministic chaos injection
    # -- durable run checkpointing & trainer crash recovery ---------------
    checkpoint_dir: str = ""         # run-snapshot directory ("" = off)
    checkpoint_interval_steps: int = 1  # snapshot every N steps (0 = only
                                        # at run start/end + failure)
    checkpoint_keep_last: int = 3    # snapshot retention (keep-last-k)
    supervise_trainer: bool = True   # warm-restart the driver from the
                                     # newest snapshot on a trainer crash
    max_trainer_restarts: int = 4    # warm-restart budget

    @property
    def samples_per_step(self) -> int:
        return self.prompts_per_step * self.group_size


@dataclass
class WorkflowResult:
    wall_time_s: float
    samples_trained: int
    throughput: float                 # samples / s
    metrics: List[dict]
    staleness_seen: List[int]
    log: EventLog
    bubble_fraction: Dict[str, float] = field(default_factory=dict)
    aux_metrics: Dict[str, List[dict]] = field(default_factory=dict)
    # per-stage table + instance busy/wait + staleness quantiles + raw
    # MetricsRegistry snapshot (see repro.core.obs.report)
    telemetry: Dict[str, Any] = field(default_factory=dict)


@dataclass
class StageSpec:
    """One RL task in the dataflow.

    Parameters
    ----------
    name: task name; becomes the TransferQueue controller name.
    inputs: columns that must be ready before a row is scheduled here.
    outputs: columns this stage writes (row updates, deferred writes, or
        columns of rows it spawns). ``version`` in a generate stage's
        outputs is written by the runner with the producing weight version.
    engine: key into the runner's engines dict.
    verb: RLAdapter method name resolved on that engine (ignored if ``fn``
        is given).
    fn: direct callable ``fn(batch, **ctx) -> stage output dict`` —
        used for pure-function stages (e.g. GAE) and legacy adapters.
    kind: "generate" (weight-receiving producer), "transform" (streaming
        map stage), "train" (the step-driving consumer), or
        "train_stream" (accumulating consumer without step semantics,
        e.g. critic updates).
    batch_size: rows per fetch; 0 uses the runner default for the kind.
    num_workers: worker threads; 0 uses the runner default for the kind.
    drives_steps: the single stage whose consumption defines training
        steps, weight publication and staleness accounting.
    kw: extra keyword arguments forwarded to every verb/fn call.
    """
    name: str
    inputs: Tuple[str, ...]
    outputs: Tuple[str, ...] = ()
    engine: str = ""
    verb: str = ""
    fn: Optional[Callable] = None
    kind: str = "transform"
    batch_size: int = 0
    num_workers: int = 0
    drives_steps: bool = False
    kw: dict = field(default_factory=dict)


class StageGraph:
    """A DAG of :class:`StageSpec` over a shared column namespace.

    ``source_columns`` are produced externally (the prompt feeder);
    every other input column must be produced by exactly one stage.
    """

    def __init__(self, source_columns: Sequence[str] = ("prompt",)):
        self.source_columns = tuple(source_columns)
        self.stages: Dict[str, StageSpec] = {}

    def add(self, spec: StageSpec) -> "StageGraph":
        if spec.name in self.stages:
            raise ValueError(f"duplicate stage {spec.name!r}")
        self.stages[spec.name] = spec
        return self

    def tasks(self) -> Dict[str, List[str]]:
        """{task_name: required columns} — the TransferQueue layout."""
        return {n: list(s.inputs) for n, s in self.stages.items()}

    def producers(self) -> Dict[str, str]:
        """column -> producing stage; raises on duplicate producers."""
        prod: Dict[str, str] = {}
        for s in self.stages.values():
            for c in s.outputs:
                if c in prod:
                    raise ValueError(
                        f"column {c!r} produced by both {prod[c]!r} "
                        f"and {s.name!r}")
                if c in self.source_columns:
                    raise ValueError(
                        f"stage {s.name!r} produces source column {c!r}")
                prod[c] = s.name
        return prod

    def validate(self) -> None:
        prod = self.producers()
        for s in self.stages.values():
            for c in s.inputs:
                if c not in self.source_columns and c not in prod:
                    raise ValueError(
                        f"stage {s.name!r} input column {c!r} has no "
                        f"producer (source columns: {self.source_columns})")
        self.topo_order()   # raises on cycles

    def topo_order(self) -> List[StageSpec]:
        """Kahn's algorithm over stage dependencies; raises on cycles."""
        prod = self.producers()
        deps: Dict[str, set] = {n: set() for n in self.stages}
        for s in self.stages.values():
            for c in s.inputs:
                p = prod.get(c)
                if p is not None and p != s.name:
                    deps[s.name].add(p)
                elif p == s.name:
                    raise ValueError(
                        f"stage {s.name!r} consumes its own output {c!r}")
        order, ready = [], [n for n, d in deps.items() if not d]
        while ready:
            n = ready.pop(0)
            order.append(n)
            for m, d in deps.items():
                d.discard(n)
                if not d and m not in order and m not in ready:
                    ready.append(m)
        if len(order) != len(self.stages):
            cyc = sorted(set(self.stages) - set(order))
            raise ValueError(f"stage graph has a cycle involving {cyc}")
        return [self.stages[n] for n in order]


# -- dataflow registry (§5.1: algorithms declare graphs; users register) ----

_DATAFLOWS: Dict[str, Callable[..., StageGraph]] = {}


def register_dataflow(name: str, builder: Callable[..., StageGraph]) -> None:
    """Register a named dataflow builder (``builder(**kw) -> StageGraph``)."""
    _DATAFLOWS[name] = builder


def build_dataflow(name: str, **kw) -> StageGraph:
    if name not in _DATAFLOWS:
        # built-in dataflows register on algorithm-module import; loaded
        # lazily here so the core layer never hard-depends on the rl layer
        import repro.rl  # noqa: F401
    if name not in _DATAFLOWS:
        raise KeyError(f"unknown dataflow {name!r}; "
                       f"registered: {sorted(_DATAFLOWS)}")
    return _DATAFLOWS[name](**kw)


class StageRunner:
    """Compiles a :class:`StageGraph` onto one shared TransferQueue and
    drives it under the configured workflow mode.

    Engines are passed as ``{key: engine}``; each stage resolves its verb
    on ``engines[spec.engine]`` unless it carries a direct ``fn``.
    The weight path (channel / sender / per-worker receivers, §4.2.3) is
    wired between the step-driving train stage and the generate stage.
    """

    def __init__(self, cfg: WorkflowConfig, graph: StageGraph, *,
                 engines: Dict[str, Any],
                 prompt_stream: Callable[[int], List[Any]],
                 log: Optional[EventLog] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 resume: Optional[dict] = None):
        """``resume`` is a run-snapshot document (``RunCheckpointer.load``)
        for cold resume: the runner starts at the snapshot's step, the
        feeder re-primes prompts from the dataset cursor, and the queue
        continues the snapshot's uid space (caller restores the engine
        states before constructing the runner)."""
        graph.validate()
        self.cfg = cfg
        self.graph = graph
        self.engines = dict(engines)
        self.prompt_stream = prompt_stream
        self.log = log or EventLog()
        self.registry = metrics if metrics is not None else get_registry()
        self._resume = resume
        resume_step = int(resume["step"]) if resume else 0
        resume_uid = int(resume.get("queue", {}).get("next_uid", 0)) \
            if resume else 0
        # declare stage kinds in topo order so gantt symbols for custom
        # stages are deterministic across runs
        self.log.register_kinds([s.name for s in graph.topo_order()])

        gens = [s for s in graph.stages.values() if s.kind == "generate"]
        drivers = [s for s in graph.stages.values() if s.drives_steps]
        if len(gens) != 1:
            raise ValueError(f"need exactly one generate stage, got "
                             f"{[s.name for s in gens]}")
        if len(drivers) != 1:
            raise ValueError(f"need exactly one drives_steps stage, got "
                             f"{[s.name for s in drivers]}")
        self.gen_stage = gens[0]
        self.driver_stage = drivers[0]
        self.transform_stages = [s for s in graph.stages.values()
                                 if s.kind == "transform"]
        self.stream_train_stages = [s for s in graph.stages.values()
                                    if s.kind == "train_stream"]

        total_rows = cfg.num_steps * cfg.samples_per_step
        # partial rollout requeues continuations as fresh source rows —
        # reserve capacity for every chunk of every group member
        gen_engine = self.engines.get(self.gen_stage.engine)
        chunk = getattr(gen_engine, "chunk_tokens", 0)
        cont_mult = 0
        if chunk:
            max_new = getattr(gen_engine, "max_new_tokens", chunk)
            cont_mult = cfg.group_size * (-(-max_new // chunk))
        capacity = (cfg.num_steps * cfg.prompts_per_step * (1 + cont_mult)
                    + total_rows)
        self.tq = TransferQueue(
            capacity=capacity, tasks=graph.tasks(),
            num_storage_units=cfg.num_storage_units, policy=cfg.policy,
            metrics=self.registry, uid_start=resume_uid)

        driver_engine = self.engines[self.driver_stage.engine] \
            if self.driver_stage.engine else None
        init_weights = getattr(driver_engine, "params", None)
        if init_weights is None:
            raise ValueError(
                f"drives_steps stage {self.driver_stage.name!r} must name "
                f"an engine exposing .params — the step driver publishes "
                f"weights to the generate stage at every step boundary")

        # ---- planner-driven worker sizing (§4.3 meets §3.3) ------------
        # every stage carries a desired pool size: hand-tuned num_workers
        # wins; specs left at 0 take the cfg default or — with
        # auto_size_workers — the cost-model sizing from
        # core/planner/elastic. Train-side stages stay single-threaded
        # (step semantics and engine gradient-accumulation state).
        self._pool_lock = threading.Lock()
        self._threads: List[threading.Thread] = []
        self._spawn_seq = 0
        self._active: Dict[str, int] = {n: 0 for n in graph.stages}
        self._desired: Dict[str, int] = {}
        for name, spec in graph.stages.items():
            if spec.drives_steps or spec.kind in ("train", "train_stream"):
                self._desired[name] = 1
            elif spec.kind == "generate":
                self._desired[name] = (spec.num_workers
                                       or cfg.num_rollout_workers)
            else:
                self._desired[name] = spec.num_workers or 1
        self.stage_costs = None
        if cfg.auto_size_workers:
            from repro.core.planner.elastic import (auto_size_workers,
                                                    estimate_stage_costs)
            self.stage_costs = estimate_stage_costs(
                graph, self.engines,
                seq_len=int(getattr(driver_engine, "seq_len", 32)),
                group_size=cfg.group_size)
            sized = auto_size_workers(graph, self.stage_costs,
                                      max_workers=cfg.max_stage_workers)
            for name, spec in graph.stages.items():
                if spec.num_workers == 0 and not spec.drives_steps \
                        and spec.kind in ("generate", "transform"):
                    self._desired[name] = sized[name]
        self.n_gen_workers = self._desired[self.gen_stage.name]
        self._elastic = None

        # one-to-many broadcast: the trainer stages ONE host snapshot per
        # step and every replica swaps from the same buffer, acking the
        # version it runs — bytes published are independent of fleet size
        self.channel = BroadcastWeightChannel(cfg.channel_bandwidth_gbps,
                                              metrics=self.registry)
        self.sender = WeightSender(
            self.channel, mode="async" if cfg.mode == "async" else "sync",
            metrics=self.registry)
        self.receivers = [
            WeightReceiver(self.channel, init_weights, version=resume_step,
                           metrics=self.registry, replica_id=i)
            for i in range(self.n_gen_workers)]
        self.stagger = StaggeredUpdateGroup(self.receivers) \
            if cfg.staggered else None
        self._driver_engine = driver_engine

        self.trainer_version = resume_step
        self._stop = threading.Event()
        self._step_done = threading.Condition()
        self.staleness_seen: List[int] = []
        self.metrics: List[dict] = []
        self.aux_metrics: Dict[str, List[dict]] = {}
        self.samples_trained = 0
        self._error: Optional[str] = None
        self._error_origin: Optional[Tuple[str, Any]] = None
        self._fail_lock = threading.Lock()

        # ---- durable run checkpointing & trainer recovery ---------------
        self._ckpt = None
        if cfg.checkpoint_dir:
            from repro.core.recovery import RunCheckpointer
            self._ckpt = RunCheckpointer(
                cfg.checkpoint_dir, keep_last=cfg.checkpoint_keep_last,
                metrics=self.registry)
        self._train_step = resume_step    # next step the driver runs
        self._feed_start = resume_step    # dataset/prompt-feed cursor
        self._trainer_epoch = 0           # bumped per warm restart (fence)
        self._trainer_restarts = 0
        self._last_snapshot_step = resume_step if resume else -1
        self._acked_uids: set = set()     # consumed watermark (dup guard)
        self._step_leases: List[Tuple[int, List[int]]] = []  # current step
        self._commit_pending: List[Tuple[int, List[int]]] = []  # completed
        if resume:
            self.metrics = [dict(m) for m in resume.get("metrics", [])]
            self.staleness_seen = [int(s) for s in
                                   resume.get("staleness_seen", [])]
            self.aux_metrics = {k: [dict(m) for m in v] for k, v in
                                (resume.get("aux_metrics") or {}).items()}
            self.samples_trained = int(resume.get(
                "samples_trained", resume_step * cfg.samples_per_step))
            self._acked_uids = set(resume.get("acked_uids", []))

        # ---- supervision & fault tolerance -----------------------------
        faults = cfg.faults
        self._faults = FaultInjector(faults, metrics=self.registry) \
            if faults is not None and faults.active else None
        self._retry_policy = RetryPolicy(
            max_attempts=cfg.max_stage_retries + 1,
            base_s=cfg.retry_backoff_s,
            seed=faults.seed if faults is not None else 0)
        self._supervisor: Optional[ReplicaSupervisor] = None
        if cfg.supervise:
            self._supervisor = ReplicaSupervisor(
                self._respawn_replica, requeue=self._requeue_replica,
                heartbeat_timeout_s=cfg.heartbeat_timeout_s,
                max_restarts=cfg.max_replica_restarts,
                on_exhausted=lambda e: self._fail(
                    self.gen_stage.name, "supervisor", e),
                stage=self.gen_stage.name, metrics=self.registry)

        # per-stage worker instrumentation (shared families, stage labels)
        m = self.registry
        self._h_batch = m.histogram(
            "stage_batch_seconds", "per-stage batch latency")
        self._c_samples = m.counter(
            "stage_samples_total", "samples produced/consumed per stage")
        self._c_tokens = m.counter(
            "stage_tokens_total", "tokens generated per stage")
        self._c_stalls = m.counter(
            "stage_stalls_total",
            "empty fetches: the stage polled with no rows ready "
            "(upstream backpressure)")
        self._h_staleness = m.histogram(
            "train_staleness",
            "observed weight-version staleness at the train consumer")
        self._g_workers = m.gauge(
            "stage_workers", "live worker threads per stage (elastic)")
        self._c_retries = m.counter(
            "stage_retries_total",
            "retryable stage failures retried in place (backoff)")
        self._c_trainer_restarts = m.counter(
            "trainer_restarts_total",
            "warm trainer restarts from a run snapshot")
        self._c_dup_dropped = m.counter(
            "rows_dropped_duplicate_total",
            "fetched rows past the durable consumed watermark dropped by "
            "the duplicate guard (never double-trained)")

    def _fail(self, stage: str, worker: Any, err: Any) -> None:
        """Record a fatal stage error and stop the run; run() re-raises.
        The FIRST failure wins when workers race (later ones are
        symptoms of the stop, not causes) and the message names the
        originating stage and worker index."""
        with self._fail_lock:
            if self._error is None:
                self._error = f"stage {stage!r} worker {worker}: {err!r}"
                self._error_origin = (stage, worker)
        self._stop.set()
        # wake any consumer blocked in tq.get() — a fatal error is
        # terminal, so waiting out the fetch timeout only delays the
        # unwind (and the final-flush / last-snapshot failure path)
        self.tq.close()
        with self._step_done:
            self._step_done.notify_all()

    # ------------------------------------------------------------------ #
    # helpers                                                             #
    # ------------------------------------------------------------------ #

    def _stage_fn(self, spec: StageSpec) -> Callable:
        if spec.fn is not None:
            return spec.fn
        return getattr(self.engines[spec.engine], spec.verb)

    @property
    def _source_col(self) -> str:
        return self.graph.source_columns[0]

    def _call_stage(self, stage: str, widx: int, thunk: Callable) -> Any:
        """Run one stage verb under the error taxonomy: deterministic
        fault injection first (chaos arm), then bounded retries with
        exponential backoff + deterministic jitter for RetryableError.
        ReplicaCrash and fatal errors propagate to _guard."""
        def _attempt():
            if self._faults is not None:
                self._faults.check(stage, widx)
            return thunk()

        return call_with_retry(
            _attempt, policy=self._retry_policy, key=f"{stage}:{widx}",
            on_retry=lambda a, e: self._c_retries.inc(stage=stage))

    # ------------------------------------------------------------------ #
    # replica supervision (generator fleet)                               #
    # ------------------------------------------------------------------ #

    def _requeue_replica(self, dead) -> int:
        """Supervisor requeue hook: return a dead replica's in-flight rows
        to the FRONT of the ready set (idempotent — a crashing replica
        requeues its own lease before reporting death) and release its
        broadcast subscription and pool slot."""
        n = self.tq.requeue(self.gen_stage.name, dead.current_lease)
        n += self.tq.requeue_consumer(self.gen_stage.name,
                                      f"rollout-{dead.rid}")
        dead.current_lease = None
        self.channel.unsubscribe(dead.rid)
        with self._pool_lock:
            if self._active[self.gen_stage.name] > 0:
                self._active[self.gen_stage.name] -= 1
                self._g_workers.labels(stage=self.gen_stage.name).set(
                    self._active[self.gen_stage.name])
        return n

    def _respawn_replica(self, dead) -> bool:
        """Supervisor respawn hook: start a replacement generate worker
        with a fresh receiver subscribed at the live trainer version."""
        if self._stop.is_set():
            return False
        spec = self.gen_stage
        with self._pool_lock:
            if self._active[spec.name] >= self._desired[spec.name]:
                return False        # elastic shrink absorbed the slot
            self._active[spec.name] += 1
            self._g_workers.labels(stage=spec.name).set(
                self._active[spec.name])
            self._spawn_worker(spec)
        return True

    # ------------------------------------------------------------------ #
    # elastic worker pools (planner-driven sizing + live rebalance)       #
    # ------------------------------------------------------------------ #

    def _pool_shrunk(self, name: str) -> bool:
        """Elastic shrink: the first worker to observe its pool above the
        desired size exits and returns its slot."""
        with self._pool_lock:
            if self._active[name] > self._desired[name]:
                self._active[name] -= 1
                self._g_workers.labels(stage=name).set(self._active[name])
                return True
        return False

    def _spawn_worker(self, spec: StageSpec) -> None:
        """Start one more worker thread for a stage (caller holds
        _pool_lock and has already counted the slot in _active)."""
        sid = self._spawn_seq
        self._spawn_seq = sid + 1
        if spec.kind == "generate":
            # a receiver constructed mid-run starts from the live trainer
            # params and catches up to the newest published version on its
            # first maybe_swap(); the broadcast channel tracks its acks
            # under a fresh replica id
            recv = WeightReceiver(self.channel, self._driver_engine.params,
                                  version=self.trainer_version,
                                  metrics=self.registry, replica_id=sid)
            self.receivers.append(recv)
            handle = self._supervisor.register(sid, None) \
                if self._supervisor is not None else None
            t = threading.Thread(
                target=self._guard,
                args=(self._generate_worker, sid, recv, handle),
                kwargs=dict(stage=spec.name, worker=sid, handle=handle),
                daemon=True)
            if handle is not None:
                handle.thread = t
        else:
            t = threading.Thread(
                target=self._guard, args=(self._transform_worker, spec, sid),
                kwargs=dict(stage=spec.name, worker=sid), daemon=True)
        self._threads.append(t)
        t.start()

    def _resize_stage(self, name: str, delta: int) -> bool:
        """ElasticController apply hook: grow/shrink a stage's pool.
        Train-side stages and (under staggered update) the generate stage
        are fixed-size."""
        spec = self.graph.stages.get(name)
        if spec is None or spec.drives_steps \
                or spec.kind not in ("generate", "transform"):
            return False
        if spec.kind == "generate" and self.cfg.staggered:
            return False            # staggered update group is fixed-size
        with self._pool_lock:
            new = self._desired[name] + delta
            if not 1 <= new <= self.cfg.max_stage_workers:
                return False
            self._desired[name] = new
            if delta > 0:
                if self._stop.is_set():
                    return False
                self._active[name] += 1
                self._g_workers.labels(stage=name).set(self._active[name])
                self._spawn_worker(spec)
        return True

    def _elastic_loop(self) -> None:
        while not self._stop.wait(self.cfg.elastic_interval_s):
            self._elastic.step()

    # ------------------------------------------------------------------ #
    # generate stage (weight-receiving producer)                          #
    # ------------------------------------------------------------------ #

    def _put_rows(self, spec: StageSpec, out_cols, rows, version,
                  c_samples, c_tokens) -> bool:
        """Write finished experience rows into the TransferQueue (the
        shared tail of batch-return and per-sample emit paths). Returns
        False after failing the run on capacity overflow."""
        if not rows:
            return True
        idxs = self.tq.next_indices(len(rows))
        if idxs[-1] >= self.tq.capacity:
            # beyond-capacity rows would be silently unschedulable
            # (controllers ignore out-of-range notifications) — fail
            # loudly instead: the graph's fan-out exceeds what the
            # cfg-derived capacity accounts for
            self._fail(spec.name, "producer", RuntimeError(
                f"overflowed queue capacity {self.tq.capacity} "
                f"(row {idxs[-1]}): generate fan-out exceeds "
                f"cfg.group_size accounting"))
            return False
        token_lens = [r.get("token_len", 0) for r in rows]
        c_samples.inc(len(rows))
        c_tokens.inc(sum(token_lens))
        for j, col in enumerate(out_cols):
            self.tq.put_batch(idxs, col, [r.get(col) for r in rows],
                              token_lens=token_lens if j == 0 else None)
        if "version" in spec.outputs:
            self.tq.put_batch(idxs, "version", [version] * len(rows))
        return True

    def _generate_worker(self, widx: int, recv: WeightReceiver,
                         handle=None) -> None:
        spec = self.gen_stage
        name = f"rollout-{widx}"
        rng = np.random.default_rng(1234 + widx)
        fn = self._stage_fn(spec)
        bs = spec.batch_size or self.cfg.rollout_batch
        out_cols = [c for c in spec.outputs if c != "version"]
        h_batch = self._h_batch.labels(stage=spec.name)
        c_samples = self._c_samples.labels(stage=spec.name)
        c_tokens = self._c_tokens.labels(stage=spec.name)
        c_stalls = self._c_stalls.labels(stage=spec.name)
        # per-sample handoff: a verb that accepts ``emit`` streams each
        # finished row into the queue the moment its sequence completes
        # (continuous batching), instead of returning them as one batch;
        # a verb that accepts ``heartbeat`` keeps the supervisor fed
        # during long rollouts so healthy replicas are never fenced
        try:
            sig = inspect.signature(fn).parameters
            supports_emit = "emit" in sig
            supports_heartbeat = "heartbeat" in sig
        except (TypeError, ValueError):
            supports_emit = supports_heartbeat = False
        while not self._stop.is_set():
            if handle is not None:
                if handle.fenced:
                    return     # declared dead; lease already requeued
                handle.beat()
            if self._pool_shrunk(spec.name):
                return
            # prompts are fetched under a lease: until this worker acks,
            # the supervisor can requeue them (front of ready set) if the
            # worker dies — no row is ever lost or handed out twice
            batch = self.tq.get(spec.name, bs, consumer=name, timeout=0.05,
                                allow_partial=True, lease=True)
            if batch is None:
                if self.tq.controllers[spec.name]._closed:
                    return
                c_stalls.inc()
                continue
            lease = batch.pop("lease", None)
            if handle is not None:
                handle.current_lease = lease
            batch.pop("indices", None)

            # ---- weight policy at the generation-iteration boundary ----
            # (checked after the prompt fetch so a worker can never pair
            # next-step prompts with pre-publish weights)
            if self.cfg.mode == "async":
                if self.stagger is not None:
                    if recv.staged_version() > recv.version and \
                            self.stagger.try_begin_update(widx):
                        with self.log.span(name, "weight_sync"):
                            recv.maybe_swap()
                        self.stagger.end_update(widx)
                else:
                    recv.maybe_swap()          # delayed update: H2D only
                floor = self.trainer_version - self.cfg.staleness
                if recv.version < floor:       # staleness gate
                    with self.log.span(name, "weight_sync"):
                        recv.wait_and_swap(floor, timeout=30.0)
            else:
                # sync modes: strictly on-policy — wait for current weights
                if recv.version < self.trainer_version:
                    with self.log.span(name, "weight_sync"):
                        recv.wait_and_swap(self.trainer_version,
                                           timeout=30.0)

            if handle is not None:
                handle.beat()      # weight waits above may be long
            n_in = len(batch[self._source_col])
            t_gen = time.monotonic()
            call_kw = dict(spec.kw)
            if supports_emit:
                v = recv.version
                # a fenced replica must not write rows: the supervisor
                # already requeued its lease, so anything this zombie
                # emits would be a duplicate
                call_kw["emit"] = lambda row: (
                    True if handle is not None and handle.fenced
                    else self._put_rows(spec, out_cols, [row], v,
                                        c_samples, c_tokens))
            if supports_heartbeat and handle is not None:
                call_kw["heartbeat"] = handle.beat
            with self.log.span(name, "generate", version=recv.version,
                               n=n_in):
                out = self._call_stage(
                    spec.name, widx,
                    lambda: fn(batch, params=recv.params, rng=rng,
                               version=recv.version, **call_kw)) or {}
            h_batch.observe(time.monotonic() - t_gen)

            if handle is not None and handle.fenced:
                # fenced mid-verb (hung-replica recovery): drop whatever
                # was not yet written and exit without acking — the
                # replacement regenerates from the requeued lease
                return
            conts = out.get("requeue") or []
            if conts:
                cidx = self.tq.next_indices(len(conts))
                self.tq.put_batch(cidx, self._source_col, conts,
                                  token_lens=[len(c["tokens"])
                                              for c in conts])
            if not self._put_rows(spec, out_cols, out.get("rows") or [],
                                  recv.version, c_samples, c_tokens):
                return
            # outputs durably in the queue -> finalize the lease
            self.tq.ack(spec.name, lease)
            if handle is not None:
                handle.current_lease = None

    # ------------------------------------------------------------------ #
    # transform stages (streaming map over rows)                          #
    # ------------------------------------------------------------------ #

    def _transform_worker(self, spec: StageSpec, widx: int) -> None:
        name = f"{spec.name}-{widx}"
        fn = self._stage_fn(spec)
        bs = spec.batch_size or self.cfg.train_micro_batch
        h_batch = self._h_batch.labels(stage=spec.name)
        c_samples = self._c_samples.labels(stage=spec.name)
        c_stalls = self._c_stalls.labels(stage=spec.name)
        while True:
            if self._pool_shrunk(spec.name):
                return
            batch = self.tq.get(spec.name, bs, consumer=name, timeout=0.05,
                                allow_partial=True)
            if batch is None:
                if self._stop.is_set() or \
                        self.tq.controllers[spec.name]._closed:
                    return
                c_stalls.inc()
                continue
            idxs = batch.pop("indices")
            t_fn = time.monotonic()
            with self.log.span(name, spec.name, n=len(idxs)):
                out = self._call_stage(
                    spec.name, widx,
                    lambda: fn(batch, indices=idxs, **spec.kw)) or {}
            h_batch.observe(time.monotonic() - t_fn)
            c_samples.inc(len(idxs))
            for col, vals in (out.get("updates") or {}).items():
                self.tq.put_batch(idxs, col, vals)
            for i, col, v in (out.get("writes") or []):
                self.tq.put(i, col, v)

    # ------------------------------------------------------------------ #
    # train stages (consumers)                                            #
    # ------------------------------------------------------------------ #

    def _driver(self) -> None:
        """Supervised step driver: runs :meth:`_driver_loop` under the
        trainer-recovery policy. A :class:`ReplicaCrash` out of the loop
        (chaos arm or a real trainer death) warm-restarts the loop from
        the newest intact run snapshot — same process, generate replicas
        keep streaming — until the restart budget is spent, after which
        the crash propagates and fails the run loudly."""
        cfg = self.cfg
        if self._ckpt is not None and \
                self._last_snapshot_step < self._train_step:
            self._write_snapshot(self._train_step)  # cover step-0 crashes
        while True:
            try:
                self._driver_loop()
            except ReplicaCrash as e:
                if self._stop.is_set():
                    return
                if not cfg.supervise_trainer or self._ckpt is None or \
                        self._trainer_restarts >= cfg.max_trainer_restarts:
                    raise
                self._recover_trainer(e)
                continue
            if self._ckpt is not None and self._error is None and \
                    self._last_snapshot_step != self._train_step:
                self._write_snapshot(self._train_step)  # clean shutdown
            return

    def _driver_loop(self) -> None:
        """The step-driving consumer: defines training steps, publishes
        weights, records observed staleness. With a checkpointer attached
        it consumes under leases (acked only once a snapshot covering the
        step is durable) and drops rows already past the consumed
        watermark — exactly-once training across restarts."""
        spec = self.driver_stage
        name = "train-0"
        cfg = self.cfg
        fn = self._stage_fn(spec)
        h_batch = self._h_batch.labels(stage=spec.name)
        c_samples = self._c_samples.labels(stage=spec.name)
        h_staleness = self._h_staleness.labels(stage=spec.name)
        use_lease = self._ckpt is not None
        for step in range(self._train_step, cfg.num_steps):
            got = 0
            while got < cfg.samples_per_step and not self._stop.is_set():
                want = (cfg.samples_per_step - got
                        if cfg.mode == "baseline"
                        else min(cfg.train_micro_batch,
                                 cfg.samples_per_step - got))
                t0 = time.monotonic()
                batch = self.tq.get(spec.name, want, consumer=name,
                                    timeout=60.0, lease=use_lease)
                self.log.record(name, "wait", t0, time.monotonic())
                if batch is None:
                    self._stop.set()
                    return
                lease = batch.pop("lease", None)
                idxs = batch.pop("indices", None) or []
                if use_lease and idxs:
                    # consumed-watermark duplicate guard: rows acked in a
                    # durable snapshot must never train twice (the window
                    # between snapshot write and lease ack requeues rows
                    # that are already in the acked set)
                    keep = [k for k, i in enumerate(idxs)
                            if i not in self._acked_uids]
                    if len(keep) < len(idxs):
                        self._c_dup_dropped.inc(len(idxs) - len(keep))
                        if not keep:
                            self.tq.ack(spec.name, lease)
                            continue
                        idxs = [idxs[k] for k in keep]
                        batch = {c: [v[k] for k in keep]
                                 for c, v in batch.items()}
                if lease is not None:
                    # tracked before the update: a crash inside fn()
                    # leaves the lease unacked, so recovery requeues
                    # this batch along with the rest of the step
                    self._step_leases.append((lease, list(idxs)))
                versions = batch.get("version")
                n = len(versions) if versions is not None \
                    else len(batch[spec.inputs[0]])
                for v in (versions or []):
                    s = self.trainer_version - v
                    self.staleness_seen.append(s)
                    h_staleness.observe(s)
                t_up = time.monotonic()
                with self.log.span(name, "update", step=step, n=n):
                    m = self._call_stage(spec.name, 0, lambda: fn(batch))
                h_batch.observe(time.monotonic() - t_up)
                c_samples.inc(n)
                if m:
                    self.metrics.append({"step": step, **m})
                got += n
                self.samples_trained += n
            if self._stop.is_set() and got < cfg.samples_per_step:
                return

            # step complete -> publish new weights
            with self.log.span(name, "weight_sync", version=step + 1):
                self.sender.publish(self._driver_engine.params, step + 1)
                if cfg.mode != "async":
                    self.sender.flush()
            with self._step_done:
                self.trainer_version = step + 1
                self._step_done.notify_all()
            self._train_step = step + 1
            if use_lease:
                self._commit_pending.extend(self._step_leases)
                del self._step_leases[:]
                if cfg.checkpoint_interval_steps > 0 and \
                        (step + 1) % cfg.checkpoint_interval_steps == 0:
                    self._write_snapshot(step + 1)

    # ------------------------------------------------------------------ #
    # durable run snapshots & trainer recovery                            #
    # ------------------------------------------------------------------ #

    def _rollout_cursor(self, version: int) -> dict:
        """Deterministic rollout-counter bases at a step boundary. Live
        engine counters race with generation for *later* steps, so the
        bases derive from the fixed per-step feed schedule instead: by
        boundary V exactly V*prompts_per_step groups and
        V*samples_per_step sequences are final."""
        cfg = self.cfg
        return {"gid": int(version) * cfg.prompts_per_step,
                "cb_next_uid": int(version) * cfg.samples_per_step}

    def _write_snapshot(self, version: int) -> None:
        """Persist the run at a step boundary, then ack the leases the
        snapshot covers (ack-on-snapshot: rows only pass the durable
        consumed watermark once the snapshot naming them acked is on
        disk — a crash in between requeues rows that are also in the
        acked set, and the duplicate guard drops them; exactly-once
        either way)."""
        cfg = self.cfg
        pending = list(self._commit_pending)
        acked = set(self._acked_uids)
        for _lease, idxs in pending:
            acked.update(idxs)
        run_state = {
            "trainer_version": int(version),
            "feed_step": int(version),
            "samples_trained": min(self.samples_trained,
                                   int(version) * cfg.samples_per_step),
            "metrics": [dict(m) for m in self.metrics],
            "staleness_seen": [int(s) for s in self.staleness_seen],
            "aux_metrics": {k: [dict(m) for m in v]
                            for k, v in self.aux_metrics.items()},
            "acked_uids": sorted(acked),
            "queue": self.tq.cursor(),
            "rollout": self._rollout_cursor(version),
            "trainer_restarts": self._trainer_restarts,
        }
        # every engine exposing a .state pytree is bundled (actor, critic);
        # streaming aux engines are captured best-effort mid-stream
        engine_states = {k: e.state for k, e in self.engines.items()
                         if hasattr(e, "state")}
        self._ckpt.save(int(version), run_state, engine_states)
        self._last_snapshot_step = int(version)
        for lease, idxs in pending:
            self.tq.ack(self.driver_stage.name, lease)
            self._acked_uids.update(idxs)
        del self._commit_pending[:len(pending)]

    def _recover_trainer(self, err) -> None:
        """Warm-restart the train stage inside the live process: fence
        the dead driver's partial work, requeue its unacked leases (front
        of ready, original consumption order), and rewind the driver
        engine + run accounting to the newest intact snapshot. Generate
        replicas keep streaming throughout — the weight channel retains
        any versions published past the snapshot, and the redone steps
        recompute identical weights, so re-publishes are no-ops."""
        spec = self.driver_stage
        self._trainer_restarts += 1
        self._trainer_epoch += 1
        self._c_trainer_restarts.inc()
        # fence: drop the dead driver's partial gradient accumulation so
        # stale optimizer writes can never land on the restored state
        del self._step_leases[:]
        del self._commit_pending[:]
        self.tq.requeue_consumer(spec.name, "train-0")
        path = self._ckpt.resolve("auto")
        if path is None:
            raise RuntimeError(
                f"trainer crashed ({err!r}) with no intact run snapshot "
                f"in {self.cfg.checkpoint_dir!r}")
        doc = self._ckpt.load(path)
        step = int(doc["step"])
        eng = self._driver_engine
        if hasattr(eng, "state"):
            eng.state, _ = self._ckpt.load_engine(
                path, self.driver_stage.engine, eng.state)
        for attr, val in (("_accum", None), ("_accum_n", 0),
                          ("_accum_metrics", []), ("version", step)):
            if hasattr(eng, attr):
                setattr(eng, attr, val)
        # rewind run accounting IN PLACE (WorkflowResult aliases these)
        del self.metrics[:]
        self.metrics.extend(dict(m) for m in doc.get("metrics", []))
        del self.staleness_seen[:]
        self.staleness_seen.extend(int(s)
                                   for s in doc.get("staleness_seen", []))
        self.samples_trained = int(doc.get(
            "samples_trained", step * self.cfg.samples_per_step))
        self._acked_uids = set(doc.get("acked_uids", []))
        self._last_snapshot_step = step
        with self._step_done:
            self.trainer_version = step
            self._train_step = step
            self._step_done.notify_all()

    def _stream_train_worker(self, spec: StageSpec) -> None:
        """Accumulating consumer without step semantics (e.g. the critic):
        streams micro-batches until the run stops, then drains."""
        name = f"{spec.name}-0"
        fn = self._stage_fn(spec)
        bs = spec.batch_size or self.cfg.train_micro_batch
        sink = self.aux_metrics.setdefault(spec.name, [])
        h_batch = self._h_batch.labels(stage=spec.name)
        c_samples = self._c_samples.labels(stage=spec.name)
        c_stalls = self._c_stalls.labels(stage=spec.name)
        while True:
            batch = self.tq.get(spec.name, bs, consumer=name, timeout=0.05,
                                allow_partial=True)
            if batch is None:
                if self._stop.is_set() or \
                        self.tq.controllers[spec.name]._closed:
                    return
                c_stalls.inc()
                continue
            batch.pop("indices", None)
            n = len(batch[spec.inputs[0]])
            t_fn = time.monotonic()
            with self.log.span(name, spec.name, n=n):
                m = self._call_stage(spec.name, 0, lambda: fn(batch))
            h_batch.observe(time.monotonic() - t_fn)
            c_samples.inc(n)
            if m:
                sink.append(m)

    # ------------------------------------------------------------------ #
    # prompt feeder — per-mode release schedule                           #
    # ------------------------------------------------------------------ #

    def _feed_prompts(self) -> None:
        cfg = self.cfg
        ahead = cfg.staleness if cfg.mode == "async" else 0
        # a cold-resumed run re-primes generation from the dataset cursor:
        # prompts below the snapshot step were trained and acked already
        for step in range(self._feed_start, cfg.num_steps):
            with self._step_done:
                while self.trainer_version < step - ahead and \
                        not self._stop.is_set():
                    self._step_done.wait(0.05)
            if self._stop.is_set():
                break
            prompts = self.prompt_stream(step)
            idxs = self.tq.next_indices(len(prompts))
            self.tq.put_batch(idxs, self._source_col, prompts,
                              token_lens=[len(p) if hasattr(p, "__len__")
                                          else 0 for p in prompts])
        self.tq.close_task(self.gen_stage.name)

    # ------------------------------------------------------------------ #
    # lifecycle                                                           #
    # ------------------------------------------------------------------ #

    def _guard(self, target, *args, stage: str = "run", worker: Any = -1,
               handle=None) -> None:
        """Worker-thread wrapper routing failures through the error
        taxonomy: :class:`ReplicaCrash` on a supervised generate replica
        triggers fleet recovery (lease requeue + respawn); anything else
        aborts the whole run loudly — attributed to its stage and worker
        — instead of dying as a silent daemon thread."""
        try:
            target(*args)
        except ReplicaCrash as e:
            if self._stop.is_set():
                return         # run already stopping; nothing to recover
            if handle is not None and self._supervisor is not None:
                # crash path: requeue our own lease synchronously so the
                # rows are back (in order) before the replacement spawns,
                # then report our death; the monitor respawns the slot
                self.tq.requeue(self.gen_stage.name, handle.current_lease)
                handle.current_lease = None
                self._supervisor.report_death(handle.rid, repr(e))
            else:
                self._fail(stage, worker, e)
        except Exception as e:                       # noqa: BLE001
            self._fail(stage, worker, e)
        else:
            if handle is not None and self._supervisor is not None:
                self._supervisor.retire(handle.rid)

    def run(self) -> WorkflowResult:
        sampler = None
        if self.cfg.metrics_jsonl:
            sampler = MetricsSampler(self.registry, self.cfg.metrics_jsonl,
                                     self.cfg.metrics_interval_s).start()
        t0 = time.monotonic()
        feeder = threading.Thread(
            target=self._guard, args=(self._feed_prompts,),
            kwargs=dict(stage="prompt_feeder", worker=0), daemon=True)
        gen_name = self.gen_stage.name
        with self._pool_lock:
            for i in range(self.n_gen_workers):
                handle = self._supervisor.register(i, None) \
                    if self._supervisor is not None else None
                t = threading.Thread(
                    target=self._guard,
                    args=(self._generate_worker, i, self.receivers[i],
                          handle),
                    kwargs=dict(stage=gen_name, worker=i, handle=handle),
                    daemon=True)
                if handle is not None:
                    handle.thread = t
                self._threads.append(t)
            for spec in self.transform_stages:
                for w in range(self._desired[spec.name]):
                    self._threads.append(threading.Thread(
                        target=self._guard,
                        args=(self._transform_worker, spec, w),
                        kwargs=dict(stage=spec.name, worker=w),
                        daemon=True))
            for spec in self.stream_train_stages:
                self._threads.append(threading.Thread(
                    target=self._guard,
                    args=(self._stream_train_worker, spec),
                    kwargs=dict(stage=spec.name, worker=0), daemon=True))
            # mid-run spawns pick worker ids above every initial index so
            # consumer names never collide within a stage
            self._spawn_seq = max(self._desired.values(), default=1)
            for name, n in self._desired.items():
                self._active[name] = n
                self._g_workers.labels(stage=name).set(n)
        monitor = None
        if self.cfg.elastic_interval_s > 0:
            from repro.core.planner.elastic import ElasticController
            self._elastic = ElasticController(
                self.graph, self.registry, self._desired, self._resize_stage,
                max_workers=self.cfg.max_stage_workers)
            monitor = threading.Thread(target=self._elastic_loop, daemon=True)
        super_mon = None
        if self._supervisor is not None:
            super_mon = threading.Thread(
                target=self._supervisor.monitor, args=(self._stop,),
                daemon=True)
        trainer = threading.Thread(
            target=self._guard, args=(self._driver,),
            kwargs=dict(stage=self.driver_stage.name, worker=0), daemon=True)
        try:
            feeder.start()
            for w in self._threads:
                w.start()
            if monitor is not None:
                monitor.start()
            if super_mon is not None:
                super_mon.start()
            trainer.start()
            trainer.join()
            self._stop.set()
            self.tq.close()
            with self._pool_lock:
                threads = list(self._threads)
            for w in threads:
                w.join(timeout=5.0)
            feeder.join(timeout=5.0)
            if monitor is not None:
                monitor.join(timeout=5.0)
            if super_mon is not None:
                super_mon.join(timeout=5.0)
        finally:
            if self._ckpt is not None and self._error is not None and \
                    self._last_snapshot_step != self._train_step:
                # abnormal exit: flush one last snapshot at the newest
                # completed boundary so a cold resume can pick up there
                # (best-effort — never masks the original failure)
                try:
                    self._write_snapshot(self._train_step)
                except Exception:                         # noqa: BLE001
                    pass
            if sampler is not None:
                sampler.stop()
        if self._error is not None:
            raise RuntimeError(f"stage-graph run failed: {self._error}")
        wall = time.monotonic() - t0
        n = self.samples_trained
        return WorkflowResult(
            wall_time_s=wall, samples_trained=n, throughput=n / wall,
            metrics=self.metrics, staleness_seen=self.staleness_seen,
            log=self.log, bubble_fraction=self.log.bubble_fraction(),
            aux_metrics=self.aux_metrics,
            telemetry=build_telemetry(self.log, self.registry, wall, n,
                                      self.staleness_seen))
