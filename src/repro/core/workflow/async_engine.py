"""Producer-consumer asynchronous RL workflow (paper §4) — legacy facade.

``AsyncRLRunner`` keeps the original two-task surface (a fused rollout
engine exposing ``generate``/``generate_chunked`` plus a train engine
exposing ``update``) but no longer hard-codes its own worker loops: it
compiles the fused shape into a two-stage :class:`StageGraph` and runs it
through the generic :class:`StageRunner` over a single shared
TransferQueue. Multi-stage dataflows (generate → ref_inference →
reward/advantage → actor/critic update) are declared in ``rl/grpo.py``
and ``rl/ppo.py`` and run through the same runner — see
``stage_graph.py`` for the mode semantics (baseline / streaming / async),
the staleness gate and delayed parameter update, all of which are owned
by the runner and therefore shared by every dataflow.

Every sample row carries the weight version that produced it; observed
staleness at consumption is recorded and property-tested:
``max(staleness) <= cfg.staleness + 1`` by construction (generation-time
gate + one-step-ahead prompt release), with mean ≤ ``staleness``.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional

from repro.core.workflow.events import EventLog
from repro.core.workflow.stage_graph import (StageGraph, StageRunner,
                                             StageSpec, WorkflowConfig,
                                             WorkflowResult)

ROLLOUT_TASK = "actor_rollout"
TRAIN_TASK = "actor_update"


class AsyncRLRunner:
    """Drives a fused rollout producer and a trainer consumer through the
    stage-graph runner under the configured workflow mode.

    rollout_engine — .generate(params, prompts, rng) ->
        list of row dicts: {prompt, response, logprob, reward,
        advantage, token_len}; one row per (prompt x G) sample. Engines
        with ``chunk_tokens > 0`` use .generate_chunked for partial
        rollout (k1.5-style, §4.2.1).
    train_engine   — .update(batch) -> metrics dict or {} (handles its
        own gradient accumulation); exposes .params.
    prompt_stream(step) — prompts for one training step.
    """

    def __init__(self, cfg: WorkflowConfig, *,
                 rollout_engine, train_engine,
                 prompt_stream: Callable[[int], List[Any]],
                 log: Optional[EventLog] = None):
        self.cfg = cfg
        self.rollout_engine = rollout_engine
        self.train_engine = train_engine
        columns = ("response", "logprob", "response_mask", "reward",
                   "advantage") + tuple(cfg.extra_columns)
        chunked = getattr(rollout_engine, "chunk_tokens", 0) > 0

        def _fused_generate(batch, *, params, rng, version=0, **kw):
            if chunked:
                rows, conts = rollout_engine.generate_chunked(
                    params, batch["prompt"], rng, version=version)
            else:
                rows = rollout_engine.generate(params, batch["prompt"], rng)
                conts = []
            return {"rows": rows, "requeue": conts}

        def _fused_update(batch, **kw):
            return train_engine.update(batch)

        graph = StageGraph(source_columns=("prompt",))
        graph.add(StageSpec(ROLLOUT_TASK, inputs=("prompt",),
                            outputs=columns + ("version",),
                            engine="rollout", fn=_fused_generate,
                            kind="generate"))
        graph.add(StageSpec(TRAIN_TASK, inputs=columns + ("version",),
                            engine="train", fn=_fused_update,
                            kind="train", drives_steps=True))
        self.runner = StageRunner(
            cfg, graph,
            engines={"rollout": rollout_engine, "train": train_engine},
            prompt_stream=prompt_stream, log=log)
        self.tq = self.runner.tq
        self.log = self.runner.log

    @property
    def metrics(self) -> List[dict]:
        return self.runner.metrics

    @property
    def staleness_seen(self) -> List[int]:
        return self.runner.staleness_seen

    def run(self) -> WorkflowResult:
        return self.runner.run()
