"""Producer-consumer asynchronous RL workflow (paper §4).

Wires TransferQueue, the rollout/train engines (via the backend adapters,
§5.2) and the weight-sync module into one of three workflow modes — the
exact configurations of the paper's Table 1 ablation:

  baseline   — conventional task-separated framework: one task effectively
               runs at a time. The trainer waits for the ENTIRE global
               batch before computing; prompts for step s+1 are released
               only after the step-s update and a blocking weight sync.
  streaming  — + TransferQueue: the trainer starts on micro-batches as
               soon as they stream in (pipeline overlap, §4.1). Still
               on-policy: rollout for step s+1 waits for weights s+1 at
               the iteration boundary (warm-up/cool-down bubbles remain).
  async      — + delayed parameter update (§4.2.2): prompts stream one
               step ahead, rollout keeps generating on weights at most
               ``staleness`` versions old while new weights stage to host
               buffers, swapping at generation boundaries. The
               producer-consumer asynchrony removes the boundary bubbles.

Every sample row carries the weight version that produced it; observed
staleness at consumption is recorded and property-tested:
``max(staleness) <= cfg.staleness + 1`` by construction (generation-time
gate + one-step-ahead prompt release), with mean ≤ ``staleness``.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.transfer_queue import TransferQueue
from repro.core.workflow.events import EventLog
from repro.core.workflow.weight_sync import (StaggeredUpdateGroup,
                                             WeightChannel, WeightReceiver,
                                             WeightSender)

ROLLOUT_TASK = "actor_rollout"
TRAIN_TASK = "actor_update"


@dataclass
class WorkflowConfig:
    mode: str = "async"               # baseline | streaming | async
    num_rollout_workers: int = 2
    rollout_batch: int = 2            # prompts per generate() call
    train_micro_batch: int = 4        # samples per trainer fetch
    prompts_per_step: int = 4         # prompts consumed per training step
    group_size: int = 4               # G responses per prompt (GRPO)
    num_steps: int = 8
    staleness: int = 1
    staggered: bool = False           # sub-step async (Fig. 8d)
    num_storage_units: int = 2
    policy: str = "fifo"
    channel_bandwidth_gbps: float = 0.0
    extra_columns: tuple = ()      # e.g. ("ref_logprob",) for GRPO+KL

    @property
    def samples_per_step(self) -> int:
        return self.prompts_per_step * self.group_size


@dataclass
class WorkflowResult:
    wall_time_s: float
    samples_trained: int
    throughput: float                 # samples / s
    metrics: List[dict]
    staleness_seen: List[int]
    log: EventLog
    bubble_fraction: Dict[str, float] = field(default_factory=dict)


class AsyncRLRunner:
    """Drives rollout workers (producer threads) and the trainer (consumer)
    through TransferQueue under the configured workflow mode."""

    def __init__(self, cfg: WorkflowConfig, *,
                 rollout_engine, train_engine,
                 prompt_stream: Callable[[int], List[Any]],
                 log: Optional[EventLog] = None):
        """
        rollout_engine — .generate(params, prompts, rng) ->
            list of row dicts: {prompt, response, logprob, reward,
            advantage, token_len}; one row per (prompt x G) sample.
        train_engine   — .update(batch) -> metrics dict or {} (handles its
            own gradient accumulation; applies the optimizer step when a
            full global batch has streamed through); exposes .params.
        prompt_stream(step) — prompts for one training step.
        """
        self.cfg = cfg
        self.rollout_engine = rollout_engine
        self.train_engine = train_engine
        self.prompt_stream = prompt_stream
        self.log = log or EventLog()

        total_rows = cfg.num_steps * cfg.samples_per_step
        # partial rollout requeues continuations as fresh prompt rows —
        # reserve capacity for every chunk of every group member
        chunk = getattr(rollout_engine, "chunk_tokens", 0)
        cont_mult = 0
        if chunk:
            max_new = getattr(rollout_engine, "max_new_tokens", chunk)
            cont_mult = cfg.group_size * (-(-max_new // chunk))
        self.tq = TransferQueue(
            capacity=cfg.num_steps * cfg.prompts_per_step * (1 + cont_mult),
            tasks={ROLLOUT_TASK: ["prompt"]},
            num_storage_units=cfg.num_storage_units, policy=cfg.policy)
        self._columns = ["prompt", "response", "logprob", "response_mask",
                         "reward", "advantage"] + list(cfg.extra_columns)
        self.xq = TransferQueue(
            capacity=total_rows,
            tasks={TRAIN_TASK: self._columns + ["version"]},
            num_storage_units=cfg.num_storage_units, policy=cfg.policy)

        self.channel = WeightChannel(cfg.channel_bandwidth_gbps)
        self.sender = WeightSender(
            self.channel, mode="async" if cfg.mode == "async" else "sync")
        self.receivers = [
            WeightReceiver(self.channel, train_engine.params, version=0)
            for _ in range(cfg.num_rollout_workers)]
        self.stagger = StaggeredUpdateGroup(self.receivers) \
            if cfg.staggered else None

        self.trainer_version = 0
        self._stop = threading.Event()
        self._step_done = threading.Condition()
        self.staleness_seen: List[int] = []
        self.metrics: List[dict] = []

    # ------------------------------------------------------------------ #
    # producers                                                           #
    # ------------------------------------------------------------------ #

    def _rollout_worker(self, widx: int) -> None:
        name = f"rollout-{widx}"
        recv = self.receivers[widx]
        rng = np.random.default_rng(1234 + widx)
        while not self._stop.is_set():
            batch = self.tq.get(ROLLOUT_TASK, self.cfg.rollout_batch,
                                consumer=name, timeout=0.05,
                                allow_partial=True)
            if batch is None:
                if self.tq.controllers[ROLLOUT_TASK]._closed:
                    return
                continue

            # ---- weight policy at the generation-iteration boundary ----
            # (checked after the prompt fetch so a worker can never pair
            # next-step prompts with pre-publish weights)
            if self.cfg.mode == "async":
                if self.stagger is not None:
                    if recv.staged_version() > recv.version and \
                            self.stagger.try_begin_update(widx):
                        with self.log.span(name, "weight_sync"):
                            recv.maybe_swap()
                        self.stagger.end_update(widx)
                else:
                    recv.maybe_swap()          # delayed update: H2D only
                floor = self.trainer_version - self.cfg.staleness
                if recv.version < floor:       # staleness gate
                    with self.log.span(name, "weight_sync"):
                        recv.wait_and_swap(floor, timeout=30.0)
            else:
                # sync modes: strictly on-policy — wait for current weights
                if recv.version < self.trainer_version:
                    with self.log.span(name, "weight_sync"):
                        recv.wait_and_swap(self.trainer_version, timeout=30.0)

            chunked = getattr(self.rollout_engine, "chunk_tokens", 0) > 0
            with self.log.span(name, "generate", version=recv.version,
                               n=len(batch["prompt"])):
                if chunked:
                    # partial rollout: unfinished sequences re-enter the
                    # prompt queue as continuations (k1.5-style, §4.2.1)
                    rows, conts = self.rollout_engine.generate_chunked(
                        recv.params, batch["prompt"], rng,
                        version=recv.version)
                else:
                    rows = self.rollout_engine.generate(
                        recv.params, batch["prompt"], rng)
                    conts = []
            if conts:
                cidx = self.tq.next_indices(len(conts))
                self.tq.put_batch(cidx, "prompt", conts,
                                  token_lens=[len(c["tokens"])
                                              for c in conts])
            if not rows:
                continue
            idxs = self.xq.next_indices(len(rows))
            for col in self._columns:
                self.xq.put_batch(idxs, col, [r.get(col) for r in rows],
                                  token_lens=[r.get("token_len", 0)
                                              for r in rows])
            self.xq.put_batch(idxs, "version", [recv.version] * len(rows))

    # ------------------------------------------------------------------ #
    # consumer (trainer)                                                  #
    # ------------------------------------------------------------------ #

    def _trainer(self) -> None:
        name = "train-0"
        cfg = self.cfg
        for step in range(cfg.num_steps):
            got = 0
            while got < cfg.samples_per_step and not self._stop.is_set():
                want = (cfg.samples_per_step - got if cfg.mode == "baseline"
                        else min(cfg.train_micro_batch,
                                 cfg.samples_per_step - got))
                t0 = time.monotonic()
                batch = self.xq.get(TRAIN_TASK, want, consumer=name,
                                    timeout=60.0)
                self.log.record(name, "wait", t0, time.monotonic())
                if batch is None:
                    self._stop.set()
                    return
                for v in batch["version"]:
                    self.staleness_seen.append(self.trainer_version - v)
                with self.log.span(name, "update", step=step,
                                   n=len(batch["version"])):
                    m = self.train_engine.update(batch)
                if m:
                    self.metrics.append({"step": step, **m})
                got += len(batch["version"])

            # step complete -> publish new weights
            with self.log.span(name, "weight_sync", version=step + 1):
                self.sender.publish(self.train_engine.params, step + 1)
                if cfg.mode != "async":
                    self.sender.flush()
            with self._step_done:
                self.trainer_version = step + 1
                self._step_done.notify_all()

    # ------------------------------------------------------------------ #
    # prompt feeder — per-mode release schedule                           #
    # ------------------------------------------------------------------ #

    def _feed_prompts(self) -> None:
        cfg = self.cfg
        ahead = cfg.staleness if cfg.mode == "async" else 0
        for step in range(cfg.num_steps):
            with self._step_done:
                while self.trainer_version < step - ahead and \
                        not self._stop.is_set():
                    self._step_done.wait(0.05)
            if self._stop.is_set():
                break
            prompts = self.prompt_stream(step)
            idxs = self.tq.next_indices(len(prompts))
            self.tq.put_batch(idxs, "prompt", prompts,
                              token_lens=[len(p) if hasattr(p, "__len__")
                                          else 0 for p in prompts])
        self.tq.close_task(ROLLOUT_TASK)

    def run(self) -> WorkflowResult:
        cfg = self.cfg
        t0 = time.monotonic()
        feeder = threading.Thread(target=self._feed_prompts, daemon=True)
        workers = [threading.Thread(target=self._rollout_worker, args=(i,),
                                    daemon=True)
                   for i in range(cfg.num_rollout_workers)]
        trainer = threading.Thread(target=self._trainer, daemon=True)
        feeder.start()
        for w in workers:
            w.start()
        trainer.start()
        trainer.join()
        self._stop.set()
        self.tq.close()
        self.xq.close()
        for w in workers:
            w.join(timeout=5.0)
        feeder.join(timeout=5.0)
        wall = time.monotonic() - t0
        n = cfg.num_steps * cfg.samples_per_step
        return WorkflowResult(
            wall_time_s=wall, samples_trained=n, throughput=n / wall,
            metrics=self.metrics, staleness_seen=self.staleness_seen,
            log=self.log, bubble_fraction=self.log.bubble_fraction())
