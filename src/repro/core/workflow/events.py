"""Execution-timeline event log → Gantt chart / bubble-fraction analysis
(paper Fig. 11).

Stage-graph workers record spans under their stage name (``generate``,
``ref_inference``, ``reward``, ``advantage``, ``values``, ``update``,
``critic_update``, ...), so per-stage pipeline overlap is directly
visible. Any kind that is not bookkeeping (``wait`` / ``weight_sync``)
counts as busy time — custom stage names are busy by default.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

IDLE_KINDS = ("wait", "weight_sync")


@dataclass
class Event:
    instance: str   # e.g. "rollout-0", "train-0"
    kind: str       # "generate" | "update" | "wait" | "weight_sync" | ...
    start: float
    end: float
    meta: dict = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.end - self.start


class EventLog:
    def __init__(self):
        self._events: List[Event] = []
        self._lock = threading.Lock()
        self.t0 = time.monotonic()

    def record(self, instance: str, kind: str, start: float, end: float,
               **meta) -> None:
        with self._lock:
            self._events.append(Event(instance, kind, start - self.t0,
                                      end - self.t0, meta))

    class _Span:
        def __init__(self, log, instance, kind, meta):
            self.log, self.instance, self.kind, self.meta = log, instance, kind, meta

        def __enter__(self):
            self.start = time.monotonic()
            return self

        def __exit__(self, *exc):
            self.log.record(self.instance, self.kind, self.start,
                            time.monotonic(), **self.meta)

    def span(self, instance: str, kind: str, **meta) -> "_Span":
        return self._Span(self, instance, kind, meta)

    # -- analysis ---------------------------------------------------------

    def events(self, instance: Optional[str] = None) -> List[Event]:
        with self._lock:
            ev = list(self._events)
        if instance:
            ev = [e for e in ev if e.instance == instance]
        return sorted(ev, key=lambda e: e.start)

    def instances(self) -> List[str]:
        with self._lock:
            return sorted({e.instance for e in self._events})

    def busy_fraction(self, instance: str, busy_kinds=None) -> float:
        """busy_kinds=None counts every kind except IDLE_KINDS as busy."""
        ev = self.events(instance)
        if not ev:
            return 0.0
        span = max(e.end for e in ev) - min(e.start for e in ev)
        if busy_kinds is None:
            busy = sum(e.duration for e in ev if e.kind not in IDLE_KINDS)
        else:
            busy = sum(e.duration for e in ev if e.kind in busy_kinds)
        return busy / max(span, 1e-9)

    def bubble_fraction(self, busy_kinds=None) -> Dict[str, float]:
        return {i: 1.0 - self.busy_fraction(i, busy_kinds)
                for i in self.instances()}

    def to_rows(self) -> List[dict]:
        return [dict(instance=e.instance, kind=e.kind, start=e.start,
                     end=e.end, **e.meta) for e in self.events()]

    def render_gantt(self, width: int = 80, busy_kinds=None) -> str:
        """ASCII Gantt chart (Fig. 11 analogue)."""
        ev = self.events()
        if not ev:
            return "(no events)"
        t_min = min(e.start for e in ev)
        t_max = max(e.end for e in ev)
        scale = width / max(t_max - t_min, 1e-9)
        sym = {"generate": "G", "update": "U", "forward": "F",
               "weight_sync": "w", "wait": ".", "reward": "r",
               "ref_inference": "R", "advantage": "A", "values": "V",
               "critic_update": "C"}
        lines = []
        for inst in self.instances():
            row = [" "] * width
            for e in self.events(inst):
                a = int((e.start - t_min) * scale)
                b = max(a + 1, int((e.end - t_min) * scale))
                ch = sym.get(e.kind, "#")
                for x in range(a, min(b, width)):
                    row[x] = ch
            lines.append(f"{inst:>12s} |{''.join(row)}|")
        return "\n".join(lines)
