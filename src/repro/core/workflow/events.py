"""Execution-timeline event log → Gantt chart / bubble-fraction analysis
(paper Fig. 11) and Perfetto-loadable Chrome trace export.

Stage-graph workers record spans under their stage name (``generate``,
``ref_inference``, ``reward``, ``advantage``, ``values``, ``update``,
``critic_update``, ...), so per-stage pipeline overlap is directly
visible. Any kind that is not bookkeeping (``wait`` / ``weight_sync``)
counts as busy time — custom stage names are busy by default.

``to_chrome_trace()`` emits the same spans as ``traceEvents`` JSON
(complete ``"X"`` events keyed by instance, meta as ``args``) loadable
in Perfetto / ``chrome://tracing``; ``benchmarks/gantt.py --trace``
writes it next to the ``BENCH_*.json`` trajectory.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

IDLE_KINDS = ("wait", "weight_sync")

# stable symbols for the built-in stage kinds; custom stages draw from
# _CUSTOM_PALETTE in registration order (see register_kinds)
BUILTIN_SYMBOLS = {"generate": "G", "update": "U", "forward": "F",
                   "weight_sync": "w", "wait": ".", "reward": "r",
                   "ref_inference": "R", "advantage": "A", "values": "V",
                   "critic_update": "C"}
_CUSTOM_PALETTE = "abcdefghijklmnopqstuvxyz0123456789"


def _merged_total(intervals: List[Tuple[float, float]]) -> float:
    """Total length of the union of intervals — overlapping spans from
    multiple workers under one instance must not double-count."""
    total = 0.0
    cur_s = cur_e = None
    for s, e in sorted(intervals):
        if cur_e is None or s > cur_e:
            if cur_e is not None:
                total += cur_e - cur_s
            cur_s, cur_e = s, e
        elif e > cur_e:
            cur_e = e
    if cur_e is not None:
        total += cur_e - cur_s
    return total


def _json_safe(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    if hasattr(v, "item"):            # numpy scalar
        try:
            return v.item()
        except Exception:              # noqa: BLE001
            pass
    return str(v)


@dataclass
class Event:
    instance: str   # e.g. "rollout-0", "train-0"
    kind: str       # "generate" | "update" | "wait" | "weight_sync" | ...
    start: float
    end: float
    meta: dict = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.end - self.start


class EventLog:
    def __init__(self):
        self._events: List[Event] = []
        self._lock = threading.Lock()
        self._kind_order: Dict[str, None] = {}   # insertion-ordered set
        self.t0 = time.monotonic()

    def record(self, instance: str, kind: str, start: float, end: float,
               **meta) -> None:
        with self._lock:
            self._events.append(Event(instance, kind, start - self.t0,
                                      end - self.t0, meta))

    def register_kinds(self, kinds: Sequence[str]) -> None:
        """Declare stage kinds up front (StageRunner registers the graph's
        stages in topological order) so gantt symbols are deterministic
        regardless of which worker thread records first."""
        with self._lock:
            for k in kinds:
                self._kind_order.setdefault(k, None)

    class _Span:
        def __init__(self, log, instance, kind, meta):
            self.log, self.instance, self.kind, self.meta = log, instance, kind, meta

        def __enter__(self):
            self.start = time.monotonic()
            return self

        def __exit__(self, *exc):
            self.log.record(self.instance, self.kind, self.start,
                            time.monotonic(), **self.meta)

    def span(self, instance: str, kind: str, **meta) -> "_Span":
        return self._Span(self, instance, kind, meta)

    # -- analysis ---------------------------------------------------------

    def events(self, instance: Optional[str] = None) -> List[Event]:
        with self._lock:
            ev = list(self._events)
        if instance:
            ev = [e for e in ev if e.instance == instance]
        return sorted(ev, key=lambda e: (e.start, e.end, e.kind))

    def instances(self) -> List[str]:
        with self._lock:
            return sorted({e.instance for e in self._events})

    def _fraction(self, instance: str, selector) -> float:
        ev = self.events(instance)
        if not ev:
            return 0.0
        span = max(e.end for e in ev) - min(e.start for e in ev)
        sel = _merged_total([(e.start, e.end) for e in ev if selector(e)])
        return sel / max(span, 1e-9)

    def busy_fraction(self, instance: str, busy_kinds=None) -> float:
        """busy_kinds=None counts every kind except IDLE_KINDS as busy.

        Overlapping spans (multiple workers recorded under one instance)
        are merged before summing, so the fraction never exceeds 1."""
        if busy_kinds is None:
            return self._fraction(instance,
                                  lambda e: e.kind not in IDLE_KINDS)
        return self._fraction(instance, lambda e: e.kind in busy_kinds)

    def wait_fraction(self, instance: str) -> float:
        """Fraction of the instance's span spent in bookkeeping waits
        (blocked fetches + weight sync), overlap-merged."""
        return self._fraction(instance, lambda e: e.kind in IDLE_KINDS)

    def bubble_fraction(self, busy_kinds=None) -> Dict[str, float]:
        return {i: 1.0 - self.busy_fraction(i, busy_kinds)
                for i in self.instances()}

    def to_rows(self) -> List[dict]:
        return [dict(instance=e.instance, kind=e.kind, start=e.start,
                     end=e.end, **e.meta) for e in self.events()]

    # -- export -----------------------------------------------------------

    def to_chrome_trace(self, path: Optional[str] = None) -> dict:
        """Perfetto / chrome://tracing ``traceEvents`` JSON: one complete
        ("X") event per span, one track (tid) per instance, meta as args.
        Returns the trace dict; also writes it to ``path`` when given."""
        insts = self.instances()
        tid = {inst: i for i, inst in enumerate(insts)}
        trace: List[dict] = [
            {"ph": "M", "name": "process_name", "pid": 0,
             "args": {"name": "asyncflow"}}]
        for inst, i in tid.items():
            trace.append({"ph": "M", "name": "thread_name", "pid": 0,
                          "tid": i, "args": {"name": inst}})
        for e in self.events():
            trace.append({
                "name": e.kind,
                "cat": "idle" if e.kind in IDLE_KINDS else "stage",
                "ph": "X",
                "ts": round(e.start * 1e6, 3),
                "dur": round(max(e.duration, 0.0) * 1e6, 3),
                "pid": 0,
                "tid": tid[e.instance],
                "args": {k: _json_safe(v) for k, v in e.meta.items()},
            })
        doc = {"traceEvents": trace, "displayTimeUnit": "ms"}
        if path:
            with open(path, "w") as fh:
                json.dump(doc, fh)
        return doc

    # -- rendering --------------------------------------------------------

    def _symbols(self, events: List[Event]) -> Dict[str, str]:
        """Stable symbol per kind: builtins keep theirs; custom kinds get
        distinct palette symbols — registered kinds first (deterministic
        by registration order), then first appearance in the timeline."""
        sym = dict(BUILTIN_SYMBOLS)
        with self._lock:
            order = list(self._kind_order)
        for e in events:
            if e.kind not in order:
                order.append(e.kind)
        used = set(sym.values())
        palette = iter(c for c in _CUSTOM_PALETTE if c not in used)
        for kind in order:
            if kind not in sym:
                sym[kind] = next(palette, "#")
        return sym

    def render_gantt(self, width: int = 80, busy_kinds=None) -> str:
        """ASCII Gantt chart (Fig. 11 analogue)."""
        ev = self.events()
        if not ev:
            return "(no events)"
        t_min = min(e.start for e in ev)
        t_max = max(e.end for e in ev)
        scale = width / max(t_max - t_min, 1e-9)
        sym = self._symbols(ev)
        lines = []
        for inst in self.instances():
            row = [" "] * width
            for e in self.events(inst):
                a = int((e.start - t_min) * scale)
                b = max(a + 1, int((e.end - t_min) * scale))
                ch = sym.get(e.kind, "#")
                for x in range(a, min(b, width)):
                    row[x] = ch
            lines.append(f"{inst:>12s} |{''.join(row)}|")
        return "\n".join(lines)
