"""Hybrid cost model (paper §4.3): the analytical half.

Closed-form FLOP / HBM-byte / collective-byte volumes per architecture and
step kind, parameterized by mesh shape. Used by
  * the roofline analysis (EXPERIMENTS.md §Roofline) — the CPU backend's
    ``cost_analysis()`` cannot multiply while-loop (layer-scan) bodies by
    their trip counts, so analytic volumes are the ground truth, cross-
    validated against an unrolled lowering on small configs;
  * the resource planner / discrete-event simulator (Fig. 10 scaling).

Assumptions (documented in EXPERIMENTS.md):
  * bf16 compute (2 bytes) for weights/activations, fp32 (4 B) optimizer;
  * flash attention on TPU — no O(S²) HBM traffic for attention;
  * backward = 2x forward FLOPs; optimizer = elementwise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import INPUT_SHAPES, ModelConfig

BF16 = 2
F32 = 4


@dataclasses.dataclass(frozen=True)
class HW:
    """Per-chip hardware constants (TPU v5e-class target)."""
    peak_flops: float = 197e12     # bf16 FLOP/s
    hbm_bw: float = 819e9          # B/s
    ici_bw: float = 50e9           # B/s per link
    hbm_bytes: float = 96e9        # capacity (v5p-class HBM assumed)
    host_net_bw: float = 25e9      # host NIC for async weight path


# ---------------------------------------------------------------------------
# FLOPs
# ---------------------------------------------------------------------------


def _attn_linear_flops(cfg: ModelConfig, tokens: float) -> float:
    d, hd = cfg.d_model, cfg.head_dim
    if cfg.attention == "mla":
        q_dim = cfg.num_heads * (cfg.qk_rope_head_dim + cfg.qk_nope_head_dim)
        f = d * q_dim if not cfg.q_lora_rank else \
            d * cfg.q_lora_rank + cfg.q_lora_rank * q_dim
        f += d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
        f += cfg.kv_lora_rank * cfg.num_heads * (cfg.qk_nope_head_dim
                                                 + cfg.v_head_dim)
        f += cfg.num_heads * cfg.v_head_dim * d
    else:
        f = d * (cfg.num_heads + 2 * cfg.num_kv_heads) * hd \
            + cfg.num_heads * hd * d
    return 2.0 * tokens * f


def _attn_quadratic_flops(cfg: ModelConfig, B: float, S: float,
                          window: int = 0) -> float:
    """Scores + PV, causal (×1/2), optionally windowed."""
    if cfg.attention == "mla":
        hd_eff = cfg.qk_rope_head_dim + cfg.qk_nope_head_dim + cfg.v_head_dim
    else:
        hd_eff = 2 * cfg.head_dim
    span = min(S, window) if window else S
    causal = 0.5 if not window or window >= S else 1.0
    return 2.0 * B * S * span * causal * cfg.num_heads * hd_eff


def _mlp_flops(cfg: ModelConfig, tokens: float, dff: int) -> float:
    mult = 3 if cfg.activation == "silu" else 2
    return 2.0 * tokens * mult * cfg.d_model * dff


def _layer_counts(cfg: ModelConfig) -> Dict[str, int]:
    if cfg.arch_type == "hybrid":
        pat = cfg.rglru_block_pattern
        n_att = sum(1 for i in range(cfg.num_layers)
                    if pat[i % len(pat)] == "attention")
        return {"attention": n_att, "recurrent": cfg.num_layers - n_att}
    if cfg.arch_type == "moe":
        return {"dense": cfg.first_dense_layers,
                "moe": cfg.num_layers - cfg.first_dense_layers}
    return {cfg.arch_type: cfg.num_layers}


def forward_flops(cfg: ModelConfig, B: float, S: float, *,
                  window: int = 0, kv_len: float = None) -> float:
    """One forward pass over B sequences of S *new* tokens (kv_len = extra
    context attended to, for decode)."""
    tokens = B * S
    total = 2.0 * tokens * cfg.d_model * cfg.vocab_size  # unembed
    if cfg.arch_type == "vlm" and S > 1:
        # vision prefix processed during train/prefill; decode attends to
        # it through the KV cache only (kv_len covers it)
        tokens = B * (S + cfg.vision_tokens)
    counts = _layer_counts(cfg)

    for kind, n in counts.items():
        if n == 0:
            continue
        if kind == "ssm":
            di, ds = cfg.d_inner, cfg.ssm_state
            per = 2.0 * tokens * (cfg.d_model * 2 * di          # in_proj
                                  + di * (cfg.ssm_dt_rank + 2 * ds)
                                  + cfg.ssm_dt_rank * di
                                  + di * cfg.d_model)            # out
            per += 6.0 * tokens * di * ds                        # scan
            total += n * per
        elif kind == "recurrent":
            w = cfg.rnn_width
            per = 2.0 * tokens * (cfg.d_model * 2 * w + 2 * w * w
                                  + w * cfg.d_model)
            per += 8.0 * tokens * w                              # RG-LRU
            per += _mlp_flops(cfg, tokens, cfg.d_ff)
            total += n * per
        elif kind == "attention":
            per = _attn_linear_flops(cfg, tokens)
            per += _attn_quadratic_flops(cfg, B, S,
                                         window=cfg.local_window)
            per += _mlp_flops(cfg, tokens, cfg.d_ff)
            total += n * per
        elif kind == "moe":
            per = _attn_linear_flops(cfg, tokens)
            if kv_len is not None:
                per += 2.0 * B * S * kv_len * cfg.num_heads * (
                    2 * cfg.head_dim if cfg.attention != "mla" else
                    cfg.qk_rope_head_dim + cfg.qk_nope_head_dim
                    + cfg.v_head_dim)
            else:
                per += _attn_quadratic_flops(cfg, B, S, window=window)
            per += 2.0 * tokens * cfg.d_model * cfg.num_experts  # router
            per += cfg.top_k * _mlp_flops(cfg, tokens, cfg.moe_d_ff)
            per += cfg.num_shared_experts * _mlp_flops(cfg, tokens,
                                                       cfg.moe_d_ff)
            total += n * per
        else:  # dense / vlm / audio decoder
            per = _attn_linear_flops(cfg, tokens)
            if kv_len is not None:
                hd_eff = (2 * cfg.head_dim if cfg.attention != "mla" else
                          cfg.qk_rope_head_dim + cfg.qk_nope_head_dim
                          + cfg.v_head_dim)
                per += 2.0 * B * S * kv_len * cfg.num_heads * hd_eff
            else:
                per += _attn_quadratic_flops(cfg, B, S, window=window)
            per += _mlp_flops(cfg, tokens, cfg.d_ff)
            total += n * per

    if cfg.arch_type == "audio":
        F = cfg.encoder_frames
        enc_tokens = B * F
        enc_per = (2.0 * enc_tokens * 4 * cfg.d_model * cfg.d_model
                   + 2.0 * 2 * B * F * F * cfg.num_heads * cfg.head_dim / 2
                   + _mlp_flops(cfg, enc_tokens, cfg.d_ff))
        total += cfg.encoder_layers * enc_per
        # cross attention in decoder
        total += cfg.num_layers * (2.0 * tokens * 4 * cfg.d_model * cfg.d_model
                                   + 2.0 * B * S * F * cfg.num_heads
                                   * cfg.head_dim * 2)
    return total


def step_flops(cfg: ModelConfig, shape_name: str) -> float:
    shp = INPUT_SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    if shp.kind == "train":
        return 3.0 * forward_flops(cfg, B, S)
    if shp.kind == "prefill":
        return forward_flops(cfg, B, S)
    # decode: 1 new token against a cache of length (window-capped) S
    from repro.models.model import decode_window
    length, _ = decode_window(cfg, shape_name)
    return forward_flops(cfg, B, 1, kv_len=length)


# ---------------------------------------------------------------------------
# HBM bytes (per chip, given mesh degree sharding)
# ---------------------------------------------------------------------------


def _kv_shard_degree(cfg: ModelConfig, tp: int, kv_seq_shard: bool) -> int:
    """How many ways the KV cache shards over the model axis: by kv heads
    when divisible, by the sequence dim under the kv_seq_shard policy
    (§Perf HC3), else replicated."""
    if cfg.arch_type in ("ssm", "hybrid"):
        return tp  # state/channel dims shard over model
    if cfg.attention == "mla":
        return tp if kv_seq_shard else 1   # latent is per-token, headless
    if cfg.num_kv_heads % max(tp, 1) == 0:
        return tp
    return tp if kv_seq_shard else 1


def step_hbm_bytes(cfg: ModelConfig, shape_name: str, n_chips: int, *,
                   mesh_shape: Dict[str, int] = None,
                   kv_seq_shard: bool = False) -> float:
    """Per-chip HBM traffic of one step (weights after sharding +
    activation reads/writes; flash attention assumed).

    Training shards weights over (data-FSDP x model); inference replicates
    weights across data, so each chip reads P/tp per token."""
    shp = INPUT_SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    P_total = cfg.param_count()
    P_active = cfg.active_param_count()
    d, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    if mesh_shape:
        tp = mesh_shape.get("model", 1)
        dp = n_chips // max(tp, 1)
    else:
        tp = min(16, n_chips)
        dp = n_chips // tp

    if shp.kind == "train":
        tokens = B * S
        # fwd reads weights + bwd reads + grads write + AdamW (p,m,v fp32
        # read+write) — weights fully sharded across chips (FSDP x TP)
        w_traffic = P_total * (BF16 * 3 + F32 * 6) / n_chips
        act = tokens * d * L * BF16 * 8 / n_chips
        logits = tokens * V * BF16 * 2 / n_chips
        return w_traffic + act + logits
    if shp.kind == "prefill":
        tokens = B * S
        w = P_active * BF16 / tp               # replicated across data
        act = tokens * d * L * BF16 * 4 / n_chips
        cache_w = kv_cache_bytes(cfg, B, S) / n_chips
        return w + act + cache_w
    # decode
    from repro.models.model import decode_window
    length, _ = decode_window(cfg, shape_name)
    w = P_active * BF16 / tp                   # whole shard read per token
    kv_deg = _kv_shard_degree(cfg, tp, kv_seq_shard)
    b_deg = dp if B % dp == 0 and B > 1 else (dp if B == 1 else 1)
    if B == 1:
        # batch can't shard; long_500k shards the seq/state dim over data
        b_deg = dp if cfg.arch_type not in ("ssm",) else 1
    cache = kv_cache_bytes(cfg, B, length) / (b_deg * kv_deg)
    return w + cache


def kv_cache_bytes(cfg: ModelConfig, B: int, length: int) -> float:
    if cfg.arch_type == "ssm":
        return B * cfg.num_layers * (cfg.d_inner * cfg.ssm_state
                                     + (cfg.ssm_conv - 1) * cfg.d_inner) * F32
    if cfg.arch_type == "hybrid":
        counts = _layer_counts(cfg)
        att = counts.get("attention", 0)
        rec = counts.get("recurrent", 0)
        return B * (att * min(length, cfg.local_window) * 2
                    * cfg.num_kv_heads * cfg.head_dim * BF16
                    + rec * 4 * cfg.rnn_width * F32)
    if cfg.attention == "mla":
        return B * cfg.num_layers * length * (cfg.kv_lora_rank
                                              + cfg.qk_rope_head_dim) * BF16
    per = 2 * cfg.num_kv_heads * cfg.head_dim * BF16
    total = B * cfg.num_layers * length * per
    if cfg.arch_type == "audio":
        total += B * cfg.num_layers * cfg.encoder_frames * per  # cross K/V
    return total


# ---------------------------------------------------------------------------
# Collective bytes (per chip)
# ---------------------------------------------------------------------------


def step_collective_bytes(cfg: ModelConfig, shape_name: str,
                          mesh_shape: Dict[str, int]) -> Dict[str, float]:
    """Per-chip collective traffic of one step under the sharding scheme of
    repro.distributed.sharding (ring-collective cost: all-reduce 2x, all-
    gather/reduce-scatter 1x the shard-aggregated payload)."""
    shp = INPUT_SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    tp = mesh_shape.get("model", 1)
    dp = mesh_shape.get("data", 1)
    pod = mesh_shape.get("pod", 1)
    d, L = cfg.d_model, cfg.num_layers
    P_total = cfg.param_count()
    out = {"tp_allreduce": 0.0, "fsdp_allgather": 0.0,
           "grad_reducescatter": 0.0, "pod_allreduce": 0.0,
           "moe_all2all": 0.0}

    if shp.kind == "decode":
        from repro.models.model import decode_window
        S_eff = 1
    else:
        S_eff = S
    tokens_local = B * S_eff / (dp * pod) if B * S_eff >= dp * pod else B * S_eff

    n_att_layers = L if cfg.arch_type != "hybrid" else \
        _layer_counts(cfg)["attention"]
    n_mix_layers = L

    if tp > 1:
        # all-reduces per layer: attn-out + ffn-out for attention blocks,
        # one out-proj for ssm blocks; ring all-reduce moves 2x payload.
        if cfg.arch_type == "ssm":
            ar_per_layer = 1.0
        elif cfg.arch_type == "hybrid":
            c = _layer_counts(cfg)
            ar_per_layer = (2 * c["attention"] + 2 * c["recurrent"]) / L
        else:
            ar_per_layer = 2.0
        per_layer = ar_per_layer * tokens_local * d * BF16 * 2 * ((tp - 1) / tp)
        mult = 2 if shp.kind == "train" else 1
        out["tp_allreduce"] = n_mix_layers * per_layer * mult

    if shp.kind == "train" and dp > 1:
        # FSDP: all-gather params fwd + bwd, reduce-scatter grads
        shard = P_total * BF16 * ((dp - 1) / dp) / tp
        out["fsdp_allgather"] = 2 * shard
        out["grad_reducescatter"] = P_total * F32 * ((dp - 1) / dp) / tp
    if shp.kind == "train" and pod > 1:
        out["pod_allreduce"] = 2 * P_total * F32 * ((pod - 1) / pod) / (dp * tp)

    if cfg.arch_type == "moe" and cfg.num_experts % max(tp, 1) == 0 and tp > 1:
        n_moe = _layer_counts(cfg)["moe"]
        # fan-out per token: top_k target devices, capped by the
        # device-limited routing bound (§Perf HC4) and by tp itself
        fan = min(cfg.top_k, tp)
        if cfg.moe_device_limit:
            fan = min(fan, cfg.moe_device_limit)
        per = 2 * tokens_local * fan * d * BF16 * ((tp - 1) / tp)
        mult = 3 if shp.kind == "train" else 1   # fwd + bwd dispatch+combine
        out["moe_all2all"] = n_moe * per * mult

    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


# ---------------------------------------------------------------------------
# Step-time estimate (for the planner/simulator)
# ---------------------------------------------------------------------------


def roofline_terms(cfg: ModelConfig, shape_name: str,
                   mesh_shape: Dict[str, int], hw: HW = HW(), *,
                   kv_seq_shard: bool = False) -> dict:
    n_chips = 1
    for v in mesh_shape.values():
        n_chips *= v
    fl = step_flops(cfg, shape_name)
    hb = step_hbm_bytes(cfg, shape_name, n_chips, mesh_shape=mesh_shape,
                        kv_seq_shard=kv_seq_shard)
    co = step_collective_bytes(cfg, shape_name, mesh_shape)
    t_c = fl / (n_chips * hw.peak_flops)
    t_m = hb / hw.hbm_bw
    t_x = co["total"] / hw.ici_bw
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    return {"flops": fl, "hbm_bytes_per_chip": hb,
            "collective_bytes_per_chip": co,
            "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
            "t_step_lower_bound": max(t_c, t_m, t_x),
            "bottleneck": dom, "n_chips": n_chips}
