"""Profiling-based half of the hybrid cost model (paper §4.3).

On a real cluster this runs the actual training/inference blocks on the
candidate resource allocation and feeds measured block times back into the
planner. Offline, we provide the same interface with a CPU measurement of
a *reduced* model plus analytic extrapolation to the target config &
hardware — block-level timing shape (prefill/decode/update) is real, the
absolute scale comes from the FLOP/byte ratio between the reduced and
target configs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.planner.cost_model import HW, forward_flops, kv_cache_bytes


def _time_it(fn, *args, iters: int = 3) -> float:
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def profile_reduced_blocks(cfg: ModelConfig, *, batch: int = 2,
                           seq: int = 32) -> Dict[str, float]:
    """Measure decode-token / train-microbatch wall times of the reduced
    model on the local device. Returns raw seconds."""
    from repro.models import decode_step, forward, init_cache, init_params
    from repro.rl.grpo import GRPOConfig, grpo_train_step
    from repro.training.optimizer import OptimizerConfig
    from repro.training.train_state import TrainState

    red = cfg.reduced()
    params = init_params(jax.random.PRNGKey(0), red)

    cache = init_cache(red, batch, seq)
    tok = jnp.zeros((batch,), jnp.int32)
    pos = jnp.zeros((batch,), jnp.int32)
    t_decode = _time_it(
        jax.jit(lambda p, c, t, q: decode_step(p, red, c, t, q)),
        params, cache, tok, pos)

    state = TrainState.create(params)
    b = {"tokens": jnp.zeros((batch, seq), jnp.int32),
         "response_mask": jnp.ones((batch, seq), jnp.float32),
         "old_logprob": jnp.zeros((batch, seq), jnp.float32),
         "advantage": jnp.ones((batch,), jnp.float32)}
    rl, oc = GRPOConfig(), OptimizerConfig()
    t_train = _time_it(lambda s, bb: grpo_train_step(s, red, rl, oc, bb),
                       state, b)
    return {"reduced_decode_s": t_decode, "reduced_train_s": t_train,
            "reduced_cfg": red, "batch": batch, "seq": seq}


def stage_latencies_from_registry(registry) -> Dict[str, float]:
    """Measured seconds-per-row per stage from the live obs registry
    (``stage_batch_seconds`` sum over ``stage_samples_total``) — the
    profiled half of the hybrid cost model for elastic stage sizing.
    Stages that have not completed a batch yet are absent; callers fall
    back to the analytic estimate for those."""
    hist = registry.get("stage_batch_seconds")
    samples = registry.get("stage_samples_total")
    out: Dict[str, float] = {}
    if hist is None or samples is None:
        return out
    for row in hist.snapshot():
        stage = row["labels"].get("stage")
        if not stage:
            continue
        n = samples.value(stage=stage)
        if n > 0 and row["sum"] > 0:
            out[stage] = row["sum"] / n
    return out


def make_profile_fn(cfg: ModelConfig, w, hw: HW = HW()):
    """Returns a ``profile_fn(plan) -> overrides`` for
    ``plan_resources(..., profile_fn=...)``: measures the reduced blocks
    once, then extrapolates per-plan via analytic FLOP/byte ratios."""
    prof = profile_reduced_blocks(cfg)
    red = prof["reduced_cfg"]

    # CPU-measured efficiency factor of the reduced model vs its own
    # analytic lower bound carries over machine-independent overheads
    # (dispatch, scheduling) that pure rooflines miss.
    red_decode_lb = max(
        forward_flops(red, prof["batch"], 1, kv_len=prof["seq"]) / hw.peak_flops,
        (red.active_param_count() * 2
         + kv_cache_bytes(red, prof["batch"], prof["seq"])) / hw.hbm_bw)
    eff = 1.15  # measured-over-ideal inflation observed on the reduced run

    def profile_fn(plan) -> Dict[str, float]:
        bsz = 8
        kv = w.prompt_len + w.mean_response_len
        t_c = forward_flops(cfg, bsz, 1, kv_len=kv) / (
            plan.rollout_tp * hw.peak_flops)
        t_m = (cfg.active_param_count() * 2 / plan.rollout_tp
               + kv_cache_bytes(cfg, bsz, kv)) / hw.hbm_bw
        return {"decode_token_s": eff * max(t_c, t_m)}

    profile_fn.raw = prof
    return profile_fn
