"""Execution-time simulator (paper §2 "hardware allocation pre-optimized
through an execution time simulator" + §4.3).

Discrete-event simulation of one RL post-training run at cluster scale:
rollout instances generate variable-length responses (lognormal tail —
the skew StreamRL/RLHFuse also model), the trainer consumes through
TransferQueue, and the workflow mode decides what overlaps:

  * colocated      — verl-like: whole cluster alternates rollout/train
                     with a resharding pause at every transition; static
                     per-DP-group prompt pre-allocation (stragglers gate
                     the switch).
  * separated      — task-separated pools, sequential (the Table-1
                     baseline): train waits for the full global batch.
  * separated_tq   — + TransferQueue: dynamic pull-based dispatch
                     (load-balanced) + micro-batch streaming overlap.
  * separated_async— + delayed parameter update: rollout never pauses at
                     iteration boundaries (≤1-step staleness).

Per-token/per-step costs come from the analytical cost model; the same
code paths accept profiled costs (hybrid cost model, §4.3).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.planner.cost_model import HW, forward_flops, kv_cache_bytes


@dataclasses.dataclass
class Workload:
    prompts_per_step: int = 512
    group_size: int = 8
    prompt_len: int = 512
    mean_response_len: int = 2048
    response_sigma: float = 0.6      # lognormal sigma (long-tail skew)
    num_steps: int = 8
    seq_len_train: int = 4096


@dataclasses.dataclass
class ClusterPlan:
    n_chips: int
    rollout_chips: int
    train_chips: int
    rollout_tp: int = 4              # chips per rollout instance
    train_tp: int = 8
    reshard_s: float = 0.0           # colocated transition cost


class CostOracle:
    """Analytical per-task costs; override entries with profiled numbers
    for the hybrid cost model."""

    def __init__(self, cfg: ModelConfig, hw: HW = HW(),
                 overrides: Optional[Dict[str, float]] = None):
        self.cfg, self.hw = cfg, hw
        self.overrides = overrides or {}

    def decode_token_s(self, batch: int, kv_len: int, chips: int) -> float:
        """One decode step for a `batch` of sequences on one instance."""
        if "decode_token_s" in self.overrides:
            return self.overrides["decode_token_s"]
        fl = forward_flops(self.cfg, batch, 1, kv_len=kv_len)
        by = (self.cfg.active_param_count() * 2
              + kv_cache_bytes(self.cfg, batch, kv_len))
        t_c = fl / (chips * self.hw.peak_flops)
        t_m = by / (chips * self.hw.hbm_bw)
        return max(t_c, t_m)

    def prefill_s(self, batch: int, seq: int, chips: int) -> float:
        fl = forward_flops(self.cfg, batch, seq)
        return fl / (chips * self.hw.peak_flops * 0.5)  # 50% MFU prefill

    def train_microbatch_s(self, n_samples: int, seq: int,
                           chips: int) -> float:
        if "train_microbatch_s" in self.overrides:
            return self.overrides["train_microbatch_s"] * n_samples
        fl = 3.0 * forward_flops(self.cfg, n_samples, seq)
        return fl / (chips * self.hw.peak_flops * 0.45)  # 45% MFU train

    def weight_sync_s(self, chips_from: int, chips_to: int,
                      host_path: bool) -> float:
        nbytes = self.cfg.param_count() * 2
        bw = self.hw.host_net_bw if host_path else self.hw.ici_bw
        return nbytes / (bw * max(1, min(chips_from, chips_to)))


def _draw_response_lens(rng, w: Workload, n: int) -> np.ndarray:
    mu = math.log(w.mean_response_len) - w.response_sigma ** 2 / 2
    return np.maximum(16, rng.lognormal(mu, w.response_sigma, n)).astype(int)


def simulate(cfg: ModelConfig, plan: ClusterPlan, w: Workload, mode: str,
             *, hw: HW = HW(), seed: int = 0,
             oracle: Optional[CostOracle] = None) -> dict:
    """Returns {"throughput_samples_per_s", "step_times", "bubble_fraction"}."""
    rng = np.random.default_rng(seed)
    oracle = oracle or CostOracle(cfg, hw)
    G = w.group_size
    samples_per_step = w.prompts_per_step * G

    if mode == "colocated":
        n_inst = max(1, plan.n_chips // plan.rollout_tp)
        step_times = []
        for _ in range(w.num_steps):
            lens = _draw_response_lens(rng, w, samples_per_step)
            # static pre-allocation: round-robin groups of samples
            per_inst = np.zeros(n_inst)
            order = rng.permutation(samples_per_step)
            for i, s in enumerate(order):
                per_inst[i % n_inst] += lens[s]
            # decode batch per instance
            bsz = max(1, samples_per_step // n_inst)
            tok_s = oracle.decode_token_s(bsz, w.prompt_len
                                          + w.mean_response_len,
                                          plan.rollout_tp)
            t_rollout = (per_inst.max() / bsz) * tok_s \
                + oracle.prefill_s(samples_per_step, w.prompt_len,
                                   plan.n_chips)
            t_train = oracle.train_microbatch_s(
                samples_per_step, w.seq_len_train, plan.n_chips)
            step_times.append(t_rollout + t_train + 2 * plan.reshard_s
                              + oracle.weight_sync_s(plan.n_chips,
                                                     plan.n_chips, False))
        wall = float(np.sum(step_times))
        busy = wall - 2 * plan.reshard_s * w.num_steps
        return _result(wall, w, busy)

    # task-separated family
    n_inst = max(1, plan.rollout_chips // plan.rollout_tp)
    bsz = max(1, samples_per_step // n_inst // 2)
    tok_s = oracle.decode_token_s(bsz, w.prompt_len + w.mean_response_len,
                                  plan.rollout_tp)
    micro = max(1, samples_per_step // 16)
    t_micro_train = oracle.train_microbatch_s(micro, w.seq_len_train,
                                              plan.train_chips)
    n_micro = samples_per_step // micro
    sync_s = oracle.weight_sync_s(plan.train_chips, plan.rollout_chips,
                                  host_path=(mode == "separated_async"))

    inst_free = np.zeros(n_inst)       # next-free time per rollout instance
    trainer_t = 0.0
    train_busy = 0.0
    step_times = []
    t_prev_step_end = 0.0
    for step in range(w.num_steps):
        lens = _draw_response_lens(rng, w, samples_per_step)
        if mode == "separated":
            # static split, full-batch wait
            per_inst = np.zeros(n_inst)
            order = rng.permutation(samples_per_step)
            for i, s in enumerate(order):
                per_inst[i % n_inst] += lens[s]
            start = max(trainer_t, inst_free.max())
            rollout_done = start + (per_inst.max() / bsz) * tok_s
            t_train = n_micro * t_micro_train
            trainer_t = rollout_done + t_train + sync_s
            train_busy += t_train
            inst_free[:] = trainer_t    # rollout idles during train + sync
        else:
            # dynamic pull (TransferQueue): greedy balance by current load
            start = inst_free.copy()
            if mode == "separated_tq":
                start = np.maximum(start, trainer_t - 0.0)
            chunks = np.array_split(rng.permutation(lens),
                                    max(1, samples_per_step // bsz))
            done_times = []
            for ch in chunks:
                i = int(np.argmin(start))
                dt = ch.sum() / bsz * tok_s
                start[i] += dt
                done_times.append((start[i], len(ch)))
            done_times.sort()
            # trainer streams micro-batches as they complete
            acc = 0
            t = trainer_t
            for done_at, k in done_times:
                acc += k
                while acc >= micro:
                    t = max(t, done_at) + t_micro_train
                    train_busy += t_micro_train
                    acc -= micro
            if acc:
                t = max(t, done_times[-1][0]) + t_micro_train * acc / micro
                train_busy += t_micro_train * acc / micro
            if mode == "separated_tq":
                # on-policy: rollout instances wait for the new weights
                trainer_t = t + sync_s
                inst_free[:] = trainer_t
            else:
                # async: weight transfer overlaps; rollout continues
                trainer_t = t
                inst_free = start
        step_times.append(trainer_t - t_prev_step_end)
        t_prev_step_end = trainer_t

    wall = trainer_t
    return _result(wall, w, train_busy)


def _result(wall: float, w: Workload, train_busy: float) -> dict:
    n = w.num_steps * w.prompts_per_step * w.group_size
    return {"throughput_samples_per_s": n / wall,
            "wall_s": wall,
            "trainer_busy_fraction": train_busy / wall}
