from repro.core.planner.cost_model import (HW, forward_flops, kv_cache_bytes,
                                           roofline_terms,
                                           step_collective_bytes, step_flops,
                                           step_hbm_bytes)
from repro.core.planner.elastic import (ElasticController, StageCost,
                                        auto_size_workers,
                                        estimate_stage_costs,
                                        simulate_stage_pipeline)
from repro.core.planner.planner import (PlanResult, candidate_plans,
                                        plan_resources)
from repro.core.planner.profiling import (make_profile_fn,
                                          profile_reduced_blocks,
                                          stage_latencies_from_registry)
from repro.core.planner.simulator import (ClusterPlan, CostOracle, Workload,
                                          simulate)

__all__ = ["HW", "roofline_terms", "step_flops", "step_hbm_bytes",
           "step_collective_bytes", "forward_flops", "kv_cache_bytes",
           "simulate", "Workload", "ClusterPlan", "CostOracle",
           "plan_resources", "PlanResult", "candidate_plans",
           "make_profile_fn", "profile_reduced_blocks",
           "stage_latencies_from_registry", "StageCost",
           "estimate_stage_costs", "auto_size_workers",
           "simulate_stage_pipeline", "ElasticController"]
