"""Graph-based task resource planner (paper §4.3).

Searches (rollout_chips : train_chips split) x (TP degrees) under a fixed
cluster size, scoring each candidate with the simulator + analytical cost
model (the fast path); candidates within ``profile_top_k`` of the best can
be re-scored with profiled costs (the accurate path) — the hybrid scheme
of the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from repro.configs.base import ModelConfig
from repro.core.planner.cost_model import HW
from repro.core.planner.simulator import (ClusterPlan, CostOracle, Workload,
                                          simulate)


@dataclasses.dataclass
class PlanResult:
    plan: ClusterPlan
    throughput: float
    candidates_scored: int


def candidate_plans(n_chips: int) -> List[ClusterPlan]:
    out = []
    for frac in (0.25, 0.375, 0.5, 0.625, 0.75):
        r = int(n_chips * frac)
        t = n_chips - r
        if r < 4 or t < 4:
            continue
        for rtp in (1, 2, 4, 8):
            if r % rtp:
                continue
            for ttp in (4, 8, 16):
                if t % ttp:
                    continue
                out.append(ClusterPlan(n_chips, r, t, rtp, ttp))
    return out


def plan_resources(cfg: ModelConfig, n_chips: int, w: Workload,
                   mode: str = "separated_async", *, hw: HW = HW(),
                   profile_fn: Optional[Callable[[ClusterPlan], dict]] = None,
                   profile_top_k: int = 3) -> PlanResult:
    cands = candidate_plans(n_chips)
    scored = []
    for plan in cands:
        r = simulate(cfg, plan, w, mode, hw=hw)
        scored.append((r["throughput_samples_per_s"], plan))
    scored.sort(key=lambda x: -x[0])

    if profile_fn is not None:
        # hybrid: re-score the shortlist with profiled block times
        best = []
        for tput, plan in scored[:profile_top_k]:
            overrides = profile_fn(plan)
            oracle = CostOracle(cfg, hw, overrides)
            r = simulate(cfg, plan, w, mode, hw=hw, oracle=oracle)
            best.append((r["throughput_samples_per_s"], plan))
        best.sort(key=lambda x: -x[0])
        tput, plan = best[0]
        return PlanResult(plan, tput, len(cands) + profile_top_k)

    tput, plan = scored[0]
    return PlanResult(plan, tput, len(cands))
