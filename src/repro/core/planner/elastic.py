"""Planner-driven elastic stage sizing (paper §4.3 meets §3.3).

Two halves, both consumed by :class:`~repro.core.workflow.StageRunner`:

1. **Static auto-sizing** — ``estimate_stage_costs`` prices every stage
   of a :class:`StageGraph` in *seconds per experience row* using the
   analytical cost model (``CostOracle``: prefill + per-token decode for
   the generate stage, one forward for inference-style verbs, 3×forward
   for train verbs), with profiled per-stage latencies (from
   ``profiling.stage_latencies_from_registry`` or any override dict)
   taking precedence. ``auto_size_workers`` then picks worker counts so
   every stage keeps up with the step-driving trainer's consumption
   rate — replacing hand-tuned ``num_workers`` wherever a spec left it
   at 0. Only the *relative* stage costs matter for sizing, so the
   analytic TPU-scale numbers transfer to the CPU-reduced runs.

2. **Live rebalance** — :class:`ElasticController` watches the
   ``core/obs`` starvation signals (``stage_stalls_total``, the
   controllers' ``tq_blocked_wait_seconds_total``) and, on sustained
   starvation of a stage, grows the worker pool of the stages producing
   its inputs (or, when those are already at the cap, shrinks the
   starved — i.e. idle — stage back toward one worker). Decisions are
   mechanical and observable: ``stage_workers{stage}`` gauges plus a
   ``stage_rebalance_total{stage, action}`` counter.

``simulate_stage_pipeline`` is the planner-side estimate of a sized
pipeline's wall time (bottleneck service rate + fill latency); tests use
it to assert elastic counts beat deliberately starved hand-tuned ones.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional

from repro.core.planner.cost_model import HW
from repro.core.planner.simulator import CostOracle

# seconds per row for pure-python fn stages (reward parsing, GAE, ...)
DEFAULT_FN_STAGE_S = 1e-4

# inference-style engine verbs priced as one forward pass
_FORWARD_VERBS = ("compute_log_prob", "compute_values")


@dataclasses.dataclass
class StageCost:
    """Estimated cost of one stage, normalized per experience row."""
    name: str
    seconds_per_row: float
    source: str          # "profiled" | "analytic" | "default"
    kind: str = "transform"


def _forward_s(oracle: CostOracle, seq: int) -> float:
    # one forward ≈ one third of the 3×forward train microbatch
    return oracle.train_microbatch_s(1, seq, 1) / 3.0


def estimate_stage_costs(graph, engines: Dict[str, Any], *,
                         seq_len: int = 32, group_size: int = 1,
                         hw: HW = HW(),
                         profiled: Optional[Dict[str, float]] = None,
                         ) -> Dict[str, StageCost]:
    """Price every stage of ``graph`` in seconds per experience row.

    ``profiled`` entries (stage name -> s/row) win over the analytic
    estimate; stages whose engine exposes no ``ModelConfig`` fall back to
    ``DEFAULT_FN_STAGE_S``.
    """
    profiled = profiled or {}
    costs: Dict[str, StageCost] = {}
    for spec in graph.stages.values():
        if spec.name in profiled:
            costs[spec.name] = StageCost(spec.name,
                                         max(profiled[spec.name], 1e-9),
                                         "profiled", spec.kind)
            continue
        engine = engines.get(spec.engine) if spec.engine else None
        model_cfg = getattr(engine, "cfg", None)
        if model_cfg is None or not hasattr(model_cfg, "vocab_size"):
            costs[spec.name] = StageCost(spec.name, DEFAULT_FN_STAGE_S,
                                         "default", spec.kind)
            continue
        oracle = CostOracle(model_cfg, hw)
        if spec.kind == "generate":
            g = max(int(getattr(engine, "group_size", group_size)), 1)
            max_new = max(int(getattr(engine, "max_new_tokens", seq_len)), 1)
            prompt_len = max(seq_len - max_new, 1)
            per_prompt = (oracle.prefill_s(g, prompt_len, 1)
                          + max_new * oracle.decode_token_s(
                              g, prompt_len + max_new, 1))
            s_row = per_prompt / g
        elif spec.kind in ("train", "train_stream"):
            s_row = oracle.train_microbatch_s(1, seq_len, 1)
        elif spec.verb in _FORWARD_VERBS:
            s_row = _forward_s(oracle, seq_len)
        else:
            # engine-backed transforms without a forward pass (reward
            # scoring etc.) are cheap relative to model stages
            s_row = DEFAULT_FN_STAGE_S
        costs[spec.name] = StageCost(spec.name, max(s_row, 1e-9),
                                     "analytic", spec.kind)
    return costs


def auto_size_workers(graph, costs: Dict[str, StageCost], *,
                      headroom: float = 1.25, max_workers: int = 8,
                      ) -> Dict[str, int]:
    """Worker counts per stage so every stage matches the step driver's
    row rate (with ``headroom`` slack), clamped to [1, max_workers].

    The drives_steps stage is the sink that defines throughput; it always
    gets exactly one worker (step semantics are single-threaded).
    """
    driver = next(s for s in graph.stages.values() if s.drives_steps)
    target_rate = 1.0 / costs[driver.name].seconds_per_row   # rows/s
    sizes: Dict[str, int] = {}
    for spec in graph.stages.values():
        if spec.name == driver.name:
            sizes[spec.name] = 1
            continue
        need = costs[spec.name].seconds_per_row * target_rate * headroom
        sizes[spec.name] = max(1, min(max_workers, math.ceil(need)))
    return sizes


def simulate_stage_pipeline(costs: Dict[str, StageCost],
                            workers: Dict[str, int], n_rows: int) -> float:
    """Planner-side wall-time estimate of a sized linear pipeline:
    ``n_rows`` through the bottleneck service rate plus one fill latency
    per stage. Monotone in worker counts — more workers on the slow
    stage is never worse."""
    rates = [workers.get(n, 1) / c.seconds_per_row for n, c in costs.items()]
    fill = sum(c.seconds_per_row for c in costs.values())
    return n_rows / min(rates) + fill


class ElasticController:
    """Live rebalance from ``core/obs`` starvation signals.

    One ``step()`` per interval reads counter deltas:

    * a stage *starves* in an interval when its empty-fetch counter
      (``stage_stalls_total{stage}``) or its controller's blocked wait
      (``tq_blocked_wait_seconds_total{task}``, summed over consumers)
      grew while no batch completed there.
    * ``patience`` consecutive starved intervals trigger a decision:
      grow the producers of the starved stage's input columns (below
      ``max_workers``), else shrink the starved stage itself (above
      ``min_workers``) — an idle pool whose upstream is maxed out only
      wastes scheduling slots.

    The controller never touches the drives_steps stage and is pure
    bookkeeping: ``apply(stage, delta)`` is the runner-provided callback
    that actually resizes pools.
    """

    def __init__(self, graph, registry, desired: Dict[str, int],
                 apply: Callable[[str, int], bool], *,
                 patience: int = 3, min_workers: int = 1,
                 max_workers: int = 8, wait_threshold_s: float = 0.05):
        self.graph = graph
        self.registry = registry
        self.desired = desired
        self.apply = apply
        self.patience = patience
        self.min_workers = min_workers
        self.max_workers = max_workers
        self.wait_threshold_s = wait_threshold_s
        self._starved: Dict[str, int] = {n: 0 for n in graph.stages}
        self._last: Dict[str, Dict[str, float]] = {}
        # producers of each stage's inputs (source columns have none)
        prod = graph.producers()
        self._upstream: Dict[str, List[str]] = {
            name: sorted({prod[c] for c in spec.inputs if c in prod})
            for name, spec in graph.stages.items()}
        self._driver = next(s.name for s in graph.stages.values()
                            if s.drives_steps)
        self._c_rebalance = registry.counter(
            "stage_rebalance_total",
            "elastic worker-pool resizes (grow/shrink) per stage")

    def _read(self, name: str) -> Dict[str, float]:
        m = self.registry
        stalls = m.counter("stage_stalls_total", "")
        waits = m.counter("tq_blocked_wait_seconds_total", "")
        batches = m.histogram("stage_batch_seconds", "")
        wait_s = sum(row["value"] for row in waits.snapshot()
                     if row["labels"].get("task") == name)
        return {"stalls": stalls.value(stage=name),
                "wait_s": wait_s,
                "batches": batches.summary(stage=name)["count"]}

    def step(self) -> List[dict]:
        """One observation interval; returns the actions taken."""
        actions: List[dict] = []
        for name in self.graph.stages:
            cur = self._read(name)
            prev = self._last.get(name, {"stalls": 0.0, "wait_s": 0.0,
                                         "batches": 0})
            self._last[name] = cur
            # Two starvation shapes: non-blocking pollers stall (counter
            # grows, no batch lands); the blocking driver instead racks up
            # tq_blocked_wait_seconds while still completing batches — so
            # blocked-wait beyond a threshold flags starvation on its own.
            starving = (cur["wait_s"] - prev["wait_s"] > self.wait_threshold_s
                        or (cur["stalls"] > prev["stalls"]
                            and cur["batches"] == prev["batches"]))
            self._starved[name] = self._starved[name] + 1 if starving else 0
            if self._starved[name] < self.patience:
                continue
            self._starved[name] = 0
            grew = False
            for up in self._upstream.get(name, []):
                if up == self._driver:
                    continue
                if self.desired.get(up, 1) < self.max_workers \
                        and self.apply(up, +1):
                    self._c_rebalance.inc(stage=up, action="grow")
                    actions.append({"stage": up, "action": "grow",
                                    "starved": name})
                    grew = True
            if not grew and name != self._driver \
                    and self.desired.get(name, 1) > self.min_workers \
                    and self.apply(name, -1):
                self._c_rebalance.inc(stage=name, action="shrink")
                actions.append({"stage": name, "action": "shrink",
                                "starved": name})
        return actions
