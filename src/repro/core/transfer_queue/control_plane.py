"""TransferQueue control plane — per-task controllers (paper §3.3).

Each RL task (actor_rollout, ref_inference, actor_update, ...) gets a
dedicated controller holding ONLY metadata: a binary data-status matrix
(row x required-column) plus consumption records. Controllers operate
independently — RL tasks never interfere algorithmically.

``request()`` implements Fig. 6: scan for rows whose required columns are
all ready and that no DP group has consumed, pack a micro-batch under a
load-balancing policy, mark consumed atomically, and hand the *metadata*
(indices) back; the consumer then reads the real data from the data plane.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.obs import MetricsRegistry, get_registry


@dataclass
class BatchMeta:
    """Metadata handed to a DP group: which rows to fetch from where.
    ``lease_id`` is set when the rows were handed out under a lease —
    the consumer must :meth:`TransferQueueController.ack` it after
    processing, or the supervisor requeues the rows on its death."""
    indices: List[int]
    columns: List[str]
    consumer: str = ""
    issued_at: float = field(default_factory=time.monotonic)
    lease_id: Optional[int] = None


class TransferQueueController:
    """Metadata + scheduling for one RL task (paper Fig. 6).

    Parameters
    ----------
    task: consumer-stage name (e.g. "actor_rollout").
    columns: data components this task needs ready before it can consume.
    capacity: number of rows tracked (global batch x group size, or more
        for async multi-step buffering).
    policy: "fifo" | "token_balance" — token_balance equalizes total token
        counts handed to each DP group (paper §3.3 proactive load balance);
        it needs a ``token_len`` hint column.
    """

    def __init__(self, task: str, columns: Sequence[str], capacity: int,
                 policy: str = "fifo",
                 metrics: Optional[MetricsRegistry] = None):
        self.task = task
        self.columns = list(columns)
        self.capacity = capacity
        self.policy = policy
        self._col_pos = {c: i for i, c in enumerate(self.columns)}
        self._ready = [[False] * len(self.columns) for _ in range(capacity)]
        self._consumed = [False] * capacity
        # incremental bookkeeping: O(1) notify, O(avail) schedule — the
        # §3.5 high-concurrency design (no O(capacity) metadata scans)
        self._n_ready_cols = [0] * capacity
        self._avail: Dict[int, None] = {}   # insertion-ordered set
        self._token_len: Dict[int, int] = {}
        self._tokens_served: Dict[str, int] = {}
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._closed = False
        # lease table (fault tolerance): rows handed out under a lease
        # stay consumed until acked; a dead consumer's leases requeue
        self._lease_seq = itertools.count(1)
        self._leases: Dict[int, dict] = {}
        # instrumentation
        self.n_requests = 0
        self.total_wait_s = 0.0
        m = metrics if metrics is not None else get_registry()
        self.metrics = m
        # pre-bound series (labels sorted once) — cheap enough to update
        # inside the scheduling lock
        self._m_requests = m.counter(
            "tq_requests_total", "scheduling requests per task").labels(
            task=task)
        self._m_rows_ready = m.counter(
            "tq_rows_ready_total",
            "rows that became schedulable per task").labels(task=task)
        self._m_rows_consumed = m.counter(
            "tq_rows_consumed_total", "rows handed to consumers per task"
        ).labels(task=task)
        self._m_depth = m.gauge(
            "tq_ready_depth",
            "rows currently ready and unconsumed (queue depth)").labels(
            task=task)
        # labelled per decision with the policy *actually used* (a
        # token_balance controller packs fifo until token hints arrive)
        self._m_sched = m.counter(
            "tq_sched_decisions_total",
            "micro-batches packed per task/policy")
        self._m_wait = m.counter(
            "tq_blocked_wait_seconds_total",
            "seconds consumers spent blocked on this task")
        self._m_requeued = m.counter(
            "rows_requeued_total",
            "leased rows returned to ready after a consumer death"
        ).labels(task=task)

    # -- metadata notification (called by storage units) ---------------------

    def _mark(self, idx: int, pos: int) -> None:
        if not self._ready[idx][pos]:
            self._ready[idx][pos] = True
            self._n_ready_cols[idx] += 1
            if self._n_ready_cols[idx] == len(self.columns) \
                    and not self._consumed[idx]:
                self._avail[idx] = None
                self._m_rows_ready.inc()
                self._m_depth.set(len(self._avail))

    def notify(self, idx: int, column: str) -> None:
        pos = self._col_pos.get(column)
        if pos is None or idx >= self.capacity:
            return
        with self._cv:
            self._mark(idx, pos)
            self._cv.notify_all()

    def notify_many(self, idxs: Sequence[int], column: str) -> None:
        pos = self._col_pos.get(column)
        if pos is None:
            return
        with self._cv:
            for i in idxs:
                if i < self.capacity:
                    self._mark(i, pos)
            self._cv.notify_all()

    def set_token_len(self, idx: int, n: int) -> None:
        with self._lock:
            self._token_len[idx] = n

    # -- scheduling (Fig. 6) --------------------------------------------------

    def _available(self) -> List[int]:
        return list(self._avail)

    def request(self, batch_size: int, consumer: str = "dp0",
                timeout: Optional[float] = None,
                allow_partial: bool = False,
                lease: bool = False) -> Optional[BatchMeta]:
        """Block until ``batch_size`` rows are ready, then consume them.

        Returns None if the queue is closed (or timed out) with nothing
        available; a partial batch if closed/``allow_partial`` with fewer.
        With ``lease=True`` the rows are tracked under a lease id until
        :meth:`ack` — if the consumer dies first, :meth:`requeue_lease`
        returns them to ready (at the front, preserving FIFO order).
        """
        t0 = time.monotonic()
        deadline = None if timeout is None else t0 + timeout
        with self._cv:
            self.n_requests += 1
            self._m_requests.inc()
            while True:
                n_avail = len(self._avail)
                if n_avail >= batch_size or \
                        (n_avail and (self._closed or allow_partial)):
                    break
                if self._closed and not n_avail:
                    self._account_wait(time.monotonic() - t0, consumer)
                    return None
                remaining = None if deadline is None \
                    else max(0.0, deadline - time.monotonic())
                if remaining == 0.0:
                    if n_avail and allow_partial:
                        break
                    self._account_wait(time.monotonic() - t0, consumer)
                    return None
                self._cv.wait(timeout=remaining if remaining is not None
                              else 0.1)
            # §3.5 instrumentation: only the blocked interval counts as
            # wait — scheduling/packing below is controller work time
            self._account_wait(time.monotonic() - t0, consumer)
            use_tb = self.policy == "token_balance" and bool(self._token_len)
            if use_tb:
                chosen = self._schedule(self._available(), batch_size,
                                        consumer)
            else:
                chosen = list(itertools.islice(self._avail, batch_size))
            for i in chosen:
                self._consumed[i] = True
                self._avail.pop(i, None)
            self._m_sched.inc(task=self.task,
                              policy="token_balance" if use_tb else "fifo")
            self._m_rows_consumed.inc(len(chosen))
            self._m_depth.set(len(self._avail))
            lease_id = None
            if lease:
                lease_id = next(self._lease_seq)
                self._leases[lease_id] = {"rows": list(chosen),
                                          "consumer": consumer}
            return BatchMeta(chosen, list(self.columns), consumer,
                             lease_id=lease_id)

    def _account_wait(self, blocked_s: float, consumer: str) -> None:
        self.total_wait_s += blocked_s
        if blocked_s > 0:
            self._m_wait.inc(blocked_s, task=self.task, consumer=consumer)

    def _schedule(self, avail: List[int], n: int, consumer: str) -> List[int]:
        n = min(n, len(avail))
        if self.policy == "token_balance" and self._token_len:
            # equalize processed tokens per DP group (paper §3.3): greedy
            # long/short alternation keeps each request's token total close
            # to n x (mean row length), so stragglers don't accumulate
            ranked = sorted(avail, key=lambda i: self._token_len.get(i, 0))
            mean_len = (sum(self._token_len.get(i, 0) for i in avail)
                        / max(1, len(avail)))
            lo, hi = 0, len(ranked) - 1
            chosen, total = [], 0.0
            for k in range(n):
                if total <= mean_len * k:      # under pace -> take longest
                    chosen.append(ranked[hi])
                    hi -= 1
                else:                           # over pace -> take shortest
                    chosen.append(ranked[lo])
                    lo += 1
                total += self._token_len.get(chosen[-1], 0)
            self._tokens_served[consumer] = \
                self._tokens_served.get(consumer, 0) + total
            return chosen
        return avail[:n]  # fifo

    # -- leases (fault tolerance) ---------------------------------------------

    def ack(self, lease_id: Optional[int]) -> None:
        """Finalize a lease: the rows were fully processed."""
        if lease_id is None:
            return
        with self._lock:
            self._leases.pop(lease_id, None)

    def requeue_lease(self, lease_id: Optional[int]) -> int:
        """Return a dead consumer's leased rows to ready. Idempotent —
        an already-acked or already-requeued lease is a no-op. Restored
        rows go to the FRONT of the ready set in their original order,
        so recovery preserves the FIFO schedule (uid/index assignment
        downstream stays deterministic under a fixed seed)."""
        if lease_id is None:
            return 0
        with self._cv:
            rec = self._leases.pop(lease_id, None)
            if rec is None:
                return 0
            rows = [i for i in rec["rows"] if self._consumed[i]]
            front: Dict[int, None] = {}
            for i in rows:
                self._consumed[i] = False
                if self._n_ready_cols[i] == len(self.columns):
                    front[i] = None
            for i in self._avail:
                front.setdefault(i, None)
            self._avail = front
            self._m_requeued.inc(len(rows))
            self._m_depth.set(len(self._avail))
            self._cv.notify_all()
            return len(rows)

    def requeue_consumer(self, consumer: str) -> int:
        """Requeue every outstanding lease held by ``consumer``.

        Leases are requeued newest-first: each ``requeue_lease`` places
        its rows at the very front, so finishing with the *oldest* lease
        leaves the ready set in original issue order — a consumer that
        held several leases (the checkpointing trainer acks only at
        snapshot boundaries) re-fetches its rows in exactly the order it
        first consumed them."""
        with self._lock:
            ids = sorted((lid for lid, rec in self._leases.items()
                          if rec["consumer"] == consumer), reverse=True)
        return sum(self.requeue_lease(lid) for lid in ids)

    def state_snapshot(self) -> dict:
        """Durable-cursor view for run snapshots: consumed/ready
        watermarks plus the in-flight leases (rows + holder)."""
        with self._lock:
            return {
                "consumed": int(sum(self._consumed)),
                "ready": len(self._avail),
                "closed": bool(self._closed),
                "leases": {int(lid): {"rows": list(rec["rows"]),
                                      "consumer": rec["consumer"]}
                           for lid, rec in self._leases.items()},
            }

    def outstanding_leases(self, consumer: Optional[str] = None) -> int:
        with self._lock:
            return sum(1 for rec in self._leases.values()
                       if consumer is None or rec["consumer"] == consumer)

    # -- lifecycle -------------------------------------------------------------

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    def reset(self, capacity: Optional[int] = None) -> None:
        with self._cv:
            if capacity is not None:
                self.capacity = capacity
            self._ready = [[False] * len(self.columns)
                           for _ in range(self.capacity)]
            self._consumed = [False] * self.capacity
            self._n_ready_cols = [0] * self.capacity
            self._avail.clear()
            self._token_len.clear()
            self._tokens_served.clear()
            self._leases.clear()
            self._closed = False
            self._cv.notify_all()

    # -- introspection ----------------------------------------------------------

    def num_ready(self) -> int:
        with self._lock:
            return len(self._available())

    def num_consumed(self) -> int:
        with self._lock:
            return sum(self._consumed)
