"""TransferQueue facade — the streaming data scheduler bridging the
training and inference clusters (paper §3.1, Fig. 3).

Wires the data plane (N storage units) to one controller per RL task and
exposes put/get plus the streaming-dataloader factory. All interaction is
thread-safe and fully streamed: consumers receive micro-batches as soon as
their required columns are ready, never waiting for the whole global batch
— this is what enables automatic pipeline overlap across RL tasks (§4.1).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Sequence, Union

from repro.core.transfer_queue.control_plane import (BatchMeta,
                                                     TransferQueueController)
from repro.core.transfer_queue.data_plane import DataPlane


class TransferQueue:
    def __init__(self, capacity: int, tasks: Dict[str, Sequence[str]],
                 num_storage_units: int = 2,
                 policy: Union[str, Dict[str, str]] = "fifo",
                 metrics=None, uid_start: int = 0):
        """tasks: {task_name: required columns}. ``policy`` is one name
        for every controller, or {task: name} overriding per consumer
        stage (missing tasks use the ``"default"`` entry, else fifo) —
        token balancing applies to *any* stage, not just the trainer.
        ``metrics`` is an optional
        :class:`repro.core.obs.MetricsRegistry` shared by every
        controller (defaults to the process-global registry).
        ``uid_start`` offsets the global row-uid counter — a cold-resumed
        run continues the uid space past its snapshot watermark so
        restored acked uids can never collide with fresh rows."""
        self.capacity = capacity
        self.data_plane = DataPlane(num_storage_units)
        self.controllers: Dict[str, TransferQueueController] = {}
        for task, cols in tasks.items():
            if isinstance(policy, dict):
                task_policy = policy.get(task, policy.get("default", "fifo"))
            else:
                task_policy = policy
            c = TransferQueueController(task, cols, capacity,
                                        policy=task_policy, metrics=metrics)
            self.controllers[task] = c
            self.data_plane.register_controller(c)
        self._next_uid = int(uid_start)
        self._idx_lock = threading.Lock()

    # -- producers -----------------------------------------------------------

    def next_indices(self, n: int) -> List[int]:
        """Reserve n fresh global row indices."""
        with self._idx_lock:
            start = self._next_uid
            self._next_uid = start + n
            return list(range(start, start + n))

    @property
    def next_uid(self) -> int:
        """The uid the next produced row will take (durable-cursor peek)."""
        with self._idx_lock:
            return self._next_uid

    def put(self, idx: int, column: str, value: Any,
            token_len: Optional[int] = None) -> None:
        if token_len is not None:
            for c in self.controllers.values():
                c.set_token_len(idx, token_len)
        self.data_plane.put(idx, column, value)

    def put_batch(self, idxs: Sequence[int], column: str,
                  values: Sequence[Any],
                  token_lens: Optional[Sequence[int]] = None) -> None:
        if token_lens is not None:
            for c in self.controllers.values():
                for i, n in zip(idxs, token_lens):
                    c.set_token_len(i, n)
        self.data_plane.put_batch(idxs, column, values)

    # -- consumers -----------------------------------------------------------

    def get(self, task: str, batch_size: int, consumer: str = "dp0",
            timeout: Optional[float] = None, allow_partial: bool = False,
            lease: bool = False) -> Optional[Dict[str, Any]]:
        """Blocking read of a micro-batch for ``task``.

        Returns {"indices": [...], <column>: [...]} or None when closed.
        With ``lease=True`` the batch carries a ``"lease"`` id the
        consumer must :meth:`ack` once processed; an unacked lease can be
        requeued if the consumer dies (fault tolerance)."""
        ctrl = self.controllers[task]
        meta = ctrl.request(batch_size, consumer, timeout=timeout,
                            allow_partial=allow_partial, lease=lease)
        if meta is None or not meta.indices:
            return None
        data = self.data_plane.get(meta.indices, meta.columns)
        data["indices"] = meta.indices
        if lease:
            data["lease"] = meta.lease_id
        return data

    def ack(self, task: str, lease_id: Optional[int]) -> None:
        self.controllers[task].ack(lease_id)

    def requeue(self, task: str, lease_id: Optional[int]) -> int:
        """Return one unacked lease's rows to ready (idempotent)."""
        return self.controllers[task].requeue_lease(lease_id)

    def requeue_consumer(self, task: str, consumer: str) -> int:
        """Return every unacked lease of a dead consumer to ready."""
        return self.controllers[task].requeue_consumer(consumer)

    def cursor(self) -> Dict[str, Any]:
        """Durable snapshot cursor: the global uid watermark plus every
        controller's consumed/ready counts and in-flight leases — what a
        :class:`repro.core.recovery.RunCheckpointer` persists so a
        resumed run knows where the stream stood."""
        return {"next_uid": self.next_uid,
                "tasks": {t: c.state_snapshot()
                          for t, c in self.controllers.items()}}

    def dataloader(self, task: str, batch_size: int, consumer: str = "dp0",
                   allow_partial: bool = True) -> "StreamingDataLoader":
        return StreamingDataLoader(self, task, batch_size, consumer,
                                   allow_partial)

    # -- lifecycle -------------------------------------------------------------

    def close_task(self, task: str) -> None:
        self.controllers[task].close()

    def close(self) -> None:
        for c in self.controllers.values():
            c.close()

    def reset(self, capacity: Optional[int] = None) -> None:
        if capacity is not None:
            self.capacity = capacity
        self.data_plane.clear()
        for c in self.controllers.values():
            c.reset(capacity)
        with self._idx_lock:
            self._next_uid = 0


class StreamingDataLoader:
    """PyTorch-DataLoader-style iterator over a TransferQueue task
    (paper §3.4, Code 1). Iterates until the queue is closed and drained.

    In a multi-rank DP group only the leader rank talks to the queue and
    broadcasts to peers (§3.5); ``consumer`` identifies the DP group.
    """

    def __init__(self, tq: TransferQueue, task: str, batch_size: int,
                 consumer: str, allow_partial: bool = True):
        self.tq = tq
        self.task = task
        self.batch_size = batch_size
        self.consumer = consumer
        self.allow_partial = allow_partial

    def __iter__(self):
        while True:
            batch = self.tq.get(self.task, self.batch_size, self.consumer,
                                allow_partial=self.allow_partial)
            if batch is None:
                return
            idxs = batch.pop("indices")
            yield batch, idxs
