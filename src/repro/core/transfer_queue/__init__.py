from repro.core.transfer_queue.control_plane import (BatchMeta,
                                                     TransferQueueController)
from repro.core.transfer_queue.data_plane import DataPlane, StorageUnit
from repro.core.transfer_queue.queue import StreamingDataLoader, TransferQueue

__all__ = ["TransferQueue", "StreamingDataLoader", "TransferQueueController",
           "BatchMeta", "DataPlane", "StorageUnit"]
