"""TransferQueue data plane — distributed storage units (paper §3.2).

Each :class:`StorageUnit` owns a subset of global row indices and stores a
2D *columnar* structure: rows are complete training samples addressed by a
global index; columns are task-specific components ("prompts",
"responses", "ref_logprobs", ...). Variable-length arrays are stored
as-is — no padding is ever materialized (paper §3.5).

On every write the unit broadcasts a metadata notification
(global index, column) to all registered controllers (paper §3.2.2) —
controllers are the control plane, see ``control_plane.py``.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Iterable, List, Sequence


class StorageUnit:
    """Owns rows where ``global_index % num_units == unit_id``."""

    def __init__(self, unit_id: int, num_units: int):
        self.unit_id = unit_id
        self.num_units = num_units
        self._data: Dict[str, Dict[int, Any]] = {}
        self._lock = threading.Lock()
        self._controllers: List = []
        # instrumentation (for §3.5 concurrency benchmarks)
        self.n_writes = 0
        self.n_reads = 0

    # -- control-plane registration ----------------------------------------

    def register_controller(self, controller) -> None:
        with self._lock:
            self._controllers.append(controller)

    # -- data path -----------------------------------------------------------

    def owns(self, idx: int) -> bool:
        return idx % self.num_units == self.unit_id

    def put(self, idx: int, column: str, value: Any) -> None:
        if not self.owns(idx):
            raise ValueError(f"unit {self.unit_id} does not own row {idx}")
        with self._lock:
            self._data.setdefault(column, {})[idx] = value
            self.n_writes += 1
            controllers = list(self._controllers)
        # metadata notification broadcast (outside the data lock — the
        # control plane and data plane pipeline concurrently, §3.5)
        for c in controllers:
            c.notify(idx, column)

    def put_many(self, idxs: Sequence[int], column: str,
                 values: Sequence[Any]) -> None:
        with self._lock:
            col = self._data.setdefault(column, {})
            for i, v in zip(idxs, values):
                if not self.owns(i):
                    raise ValueError(f"unit {self.unit_id} does not own {i}")
                col[i] = v
            self.n_writes += len(idxs)
            controllers = list(self._controllers)
        for c in controllers:
            c.notify_many(idxs, column)

    def get(self, idxs: Iterable[int], columns: Sequence[str]) -> Dict[str, list]:
        with self._lock:
            self.n_reads += 1
            out: Dict[str, list] = {}
            for c in columns:
                col = self._data.get(c)
                vals = []
                for i in idxs:
                    if col is None or i not in col:
                        raise KeyError(
                            f"storage unit {self.unit_id}: row {i} has no "
                            f"value for column {c!r}")
                    vals.append(col[i])
                out[c] = vals
            return out

    def clear(self) -> None:
        with self._lock:
            self._data.clear()


class DataPlane:
    """The set of storage units; rows are striped round-robin across units
    so storage and I/O bandwidth scale with ``num_units`` (paper §3.5)."""

    def __init__(self, num_units: int = 2):
        self.units = [StorageUnit(u, num_units) for u in range(num_units)]

    def register_controller(self, controller) -> None:
        for u in self.units:
            u.register_controller(controller)

    def unit_for(self, idx: int) -> StorageUnit:
        return self.units[idx % len(self.units)]

    def put(self, idx: int, column: str, value: Any) -> None:
        self.unit_for(idx).put(idx, column, value)

    def put_batch(self, idxs: Sequence[int], column: str,
                  values: Sequence[Any]) -> None:
        per_unit: Dict[int, list] = {}
        for i, v in zip(idxs, values):
            per_unit.setdefault(i % len(self.units), []).append((i, v))
        for uid, pairs in per_unit.items():
            self.units[uid].put_many([p[0] for p in pairs], column,
                                     [p[1] for p in pairs])

    def get(self, idxs: Sequence[int], columns: Sequence[str]) -> Dict[str, list]:
        """Gather rows (possibly spread over units), preserving idx order."""
        per_unit: Dict[int, list] = {}
        for pos, i in enumerate(idxs):
            per_unit.setdefault(i % len(self.units), []).append((pos, i))
        out: Dict[str, list] = {c: [None] * len(idxs) for c in columns}
        for uid, pairs in per_unit.items():
            vals = self.units[uid].get([i for _, i in pairs], columns)
            for c in columns:
                for (pos, _), v in zip(pairs, vals[c]):
                    out[c][pos] = v
        return out

    def clear(self) -> None:
        for u in self.units:
            u.clear()
