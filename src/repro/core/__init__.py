"""AsyncFlow core — the paper's contributions:

  transfer_queue/  C1: streaming dataloader (control plane + data plane)
  workflow/        C2: producer-consumer async workflow, delayed param update
  planner/         C4: hybrid cost model + simulator + resource planner
(C3, the service-oriented interface, lives in repro.api / repro.engines.)
"""
