"""Replica supervision: service registry, heartbeats, crash recovery.

:class:`ReplicaSupervisor` owns the generate-side replica fleet behind a
small service registry (monarch-style ``__supervise__`` / LlamaRL's
parent-supervised failure recovery). Each replica worker registers a
:class:`ReplicaHandle`, heartbeats it every iteration, and either
retires it on clean exit or reports its own death on a crash. A monitor
pass (:meth:`ReplicaSupervisor.poll`) additionally detects replicas that
died without reporting — thread no longer alive, or heartbeat stale
beyond the timeout (hung replica) — and for every dead replica:

1. **fences** it (a zombie thread that wakes up later must not write
   rows or ack leases — prevents duplicated experience),
2. **requeues** its in-flight work through the caller's requeue hook
   (leased TransferQueue rows return to ready; partial rollouts re-enter
   the source column and re-prefill deterministically),
3. **respawns** a replacement through the caller's spawn hook, counting
   against a bounded restart budget; exhausting the budget invokes the
   ``on_exhausted`` hook so the run still fails loudly instead of
   flapping forever.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.obs import get_registry
from repro.core.supervision.errors import SupervisionExhausted

__all__ = ["ReplicaHandle", "ReplicaSupervisor"]

LIVE, DEAD, RETIRED = "live", "dead", "retired"


@dataclass
class ReplicaHandle:
    """Registry entry for one replica worker."""
    rid: int
    thread: Optional[threading.Thread]
    stage: str = "generate"
    state: str = LIVE
    reason: str = ""
    recovered: bool = False           # collected by a monitor pass already
    last_beat: float = field(default_factory=time.monotonic)
    current_lease: Optional[int] = None
    fence: threading.Event = field(default_factory=threading.Event)

    def beat(self) -> None:
        self.last_beat = time.monotonic()

    @property
    def fenced(self) -> bool:
        return self.fence.is_set()


class ReplicaSupervisor:
    """Parameters
    ----------
    respawn: ``respawn(dead) -> bool`` — spawn (and register) a
        replacement replica; False means respawn was refused (e.g. the
        run is stopping) and is not counted against the budget.
    requeue: ``requeue(dead) -> int`` — return the dead replica's
        in-flight rows to the ready queue; returns the row count.
    heartbeat_timeout_s: a live replica whose last heartbeat is older
        than this is declared dead (hung) by :meth:`poll`; <= 0 disables
        the staleness check (thread-death detection still applies).
    max_restarts: total respawn budget for the fleet (0 = unlimited).
    on_exhausted: called once with a :class:`SupervisionExhausted` when
        the budget is spent and another replica dies.
    """

    def __init__(self, respawn: Callable[[ReplicaHandle], bool], *,
                 requeue: Optional[Callable[[ReplicaHandle], int]] = None,
                 heartbeat_timeout_s: float = 10.0,
                 max_restarts: int = 8,
                 on_exhausted: Optional[Callable] = None,
                 stage: str = "generate", metrics=None):
        self._respawn = respawn
        self._requeue = requeue
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.max_restarts = max_restarts
        self._on_exhausted = on_exhausted
        self.stage = stage
        self._lock = threading.Lock()
        self._registry: Dict[int, ReplicaHandle] = {}
        self.restarts = 0
        self.deaths = 0
        m = metrics if metrics is not None else get_registry()
        self._m_restarts = m.counter(
            "replica_restarts_total",
            "crashed replicas respawned by the supervisor")
        self._m_fleet = m.gauge(
            "replica_fleet_size", "live replicas in the service registry")

    # -- service registry -------------------------------------------------

    def register(self, rid: int, thread: Optional[threading.Thread],
                 stage: Optional[str] = None) -> ReplicaHandle:
        h = ReplicaHandle(rid=rid, thread=thread,
                          stage=stage or self.stage)
        with self._lock:
            self._registry[rid] = h
            self._update_fleet_gauge()
        return h

    def replicas(self, state: Optional[str] = LIVE) -> List[ReplicaHandle]:
        with self._lock:
            return [h for h in self._registry.values()
                    if state is None or h.state == state]

    def get(self, rid: int) -> Optional[ReplicaHandle]:
        with self._lock:
            return self._registry.get(rid)

    def _update_fleet_gauge(self) -> None:
        live = sum(1 for h in self._registry.values() if h.state == LIVE)
        self._m_fleet.set(live, stage=self.stage)

    # -- replica-side lifecycle -------------------------------------------

    def heartbeat(self, rid: int) -> None:
        h = self.get(rid)
        if h is not None:
            h.beat()

    def report_death(self, rid: int, reason: str = "") -> None:
        """A replica announces its own crash (its lease was already
        requeued by the crashing worker)."""
        with self._lock:
            h = self._registry.get(rid)
            if h is not None and h.state == LIVE:
                h.state = DEAD
                h.reason = reason
                h.fence.set()
                self.deaths += 1
                self._update_fleet_gauge()

    def retire(self, rid: int) -> None:
        """Clean exit (drained queue, elastic shrink) — not a crash."""
        with self._lock:
            h = self._registry.get(rid)
            if h is not None and h.state == LIVE:
                h.state = RETIRED
                self._update_fleet_gauge()

    # -- monitor -----------------------------------------------------------

    def _find_dead(self) -> List[ReplicaHandle]:
        now = time.monotonic()
        dead = []
        with self._lock:
            for h in self._registry.values():
                if h.recovered:
                    continue
                if h.state == DEAD:
                    h.recovered = True
                    dead.append(h)
                elif h.state == LIVE:
                    hung = self.heartbeat_timeout_s > 0 and \
                        now - h.last_beat > self.heartbeat_timeout_s
                    exited = h.thread is not None and h.thread.ident \
                        is not None and not h.thread.is_alive()
                    if hung or exited:
                        h.state = DEAD
                        h.reason = "heartbeat timeout" if hung \
                            else "thread exited unexpectedly"
                        h.fence.set()
                        h.recovered = True
                        self.deaths += 1
                        dead.append(h)
            if dead:
                self._update_fleet_gauge()
        return dead

    def poll(self) -> int:
        """One monitor pass: recover every dead replica. Returns the
        number of replicas respawned."""
        respawned = 0
        for h in self._find_dead():
            if self._requeue is not None:
                self._requeue(h)
            if self.max_restarts > 0 and self.restarts >= self.max_restarts:
                h.reason = f"not respawned (budget): {h.reason}"
                if self._on_exhausted is not None:
                    self._on_exhausted(SupervisionExhausted(
                        f"replica restart budget ({self.max_restarts}) "
                        f"exhausted; replica {h.rid} died: {h.reason}"))
                continue
            if self._respawn(h):
                with self._lock:
                    self.restarts += 1
                h.reason = f"respawned: {h.reason}"
                self._m_restarts.inc(stage=h.stage)
                respawned += 1
            else:
                h.reason = f"respawn refused: {h.reason}"
        return respawned

    def monitor(self, stop: threading.Event, interval_s: float = 0.05
                ) -> None:
        """Monitor loop body for a daemon thread; drains one final poll
        after stop so late deaths are still recorded."""
        while not stop.wait(interval_s):
            self.poll()
