"""Error taxonomy for the supervised streaming runtime.

The stage graph distinguishes three failure classes (LlamaRL's
parent-supervised recovery / Laminar's trajectory-level fault tolerance):

* :class:`RetryableError` — transient stage failures (flaky I/O, a
  momentarily exhausted KV pool, an injected soft fault). Workers retry
  the same call in place with exponential backoff + deterministic jitter
  and bounded attempts; exhausting the budget escalates to a loud
  failure.
* :class:`ReplicaCrash` — the replica itself died (process-level crash
  in a real deployment; a worker-thread death here). Recoverable at the
  *fleet* level: the supervisor requeues the replica's leased rows and
  respawns a replacement. Never retried in place — the crashed worker's
  state is gone.
* everything else — fatal. The run fails loudly with the originating
  stage name and worker index attached (never as a silent daemon
  death).

External exception types (e.g. an engine's pool-exhaustion error) can be
declared transient with :func:`register_retryable` without importing
this layer into the engine's hot path.
"""
from __future__ import annotations

from typing import Tuple, Type

__all__ = ["ReplicaCrash", "RetryableError", "SupervisionExhausted",
           "TransientStageError", "WeightSyncTimeout", "is_retryable",
           "register_retryable"]


class RetryableError(Exception):
    """Transient failure: safe to retry the same call after a backoff."""


class TransientStageError(RetryableError):
    """A stage call failed transiently (also raised by fault injection)."""


class ReplicaCrash(Exception):
    """A replica died mid-flight. Fleet-level recovery: requeue its
    in-flight work and respawn — never retried in place."""

    def __init__(self, msg: str = "replica crash", *, replica: int = -1):
        super().__init__(msg)
        self.replica = replica


class SupervisionExhausted(RuntimeError):
    """The supervisor hit its restart budget — recovery gave up."""


class WeightSyncTimeout(RuntimeError):
    """A weight wait timed out. Carries the version the caller waited
    for and the newest version the channel had actually seen, so a
    timeout is never mistaken for a successful no-op."""

    def __init__(self, waited_for: int, latest_seen: int,
                 timeout_s: float = 0.0):
        self.waited_for = waited_for
        self.latest_seen = latest_seen
        self.timeout_s = timeout_s
        super().__init__(
            f"timed out after {timeout_s:.1f}s waiting for weight version "
            f">= {waited_for} (latest version seen: {latest_seen})")


_EXTRA_RETRYABLE: Tuple[Type[BaseException], ...] = ()


def register_retryable(exc_type: Type[BaseException]) -> None:
    """Declare an external exception type transient (idempotent)."""
    global _EXTRA_RETRYABLE
    if exc_type not in _EXTRA_RETRYABLE:
        _EXTRA_RETRYABLE = _EXTRA_RETRYABLE + (exc_type,)


def is_retryable(exc: BaseException) -> bool:
    if isinstance(exc, (ReplicaCrash, WeightSyncTimeout)):
        return False
    return isinstance(exc, (RetryableError,) + _EXTRA_RETRYABLE)
