"""Bounded retry with exponential backoff + deterministic jitter.

The jitter is a pure function of (seed, attempt) so two runs of the same
fixed-seed workload sleep identically — chaos runs stay reproducible,
which the crash-recovery determinism tests rely on.
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.supervision.errors import is_retryable

__all__ = ["RetryPolicy", "call_with_retry"]


def _unit_hash(*parts) -> float:
    """Deterministic uniform in [0, 1) from the given key parts."""
    key = ":".join(str(p) for p in parts).encode()
    h = hashlib.blake2b(key, digest_size=8).digest()
    return int.from_bytes(h, "big") / 2.0 ** 64


@dataclass(frozen=True)
class RetryPolicy:
    """``max_attempts`` total calls (1 = no retries); backoff for attempt
    ``k`` (0-based failure count) is ``base_s * multiplier**k`` capped at
    ``max_backoff_s``, scaled by a deterministic jitter factor in
    ``[1 - jitter, 1)``."""
    max_attempts: int = 3
    base_s: float = 0.05
    multiplier: float = 2.0
    max_backoff_s: float = 2.0
    jitter: float = 0.5
    seed: int = 0

    def backoff_s(self, attempt: int, key: str = "") -> float:
        raw = min(self.base_s * self.multiplier ** attempt,
                  self.max_backoff_s)
        u = _unit_hash(self.seed, key, attempt)
        return raw * (1.0 - self.jitter * u)


def call_with_retry(fn: Callable, *args, policy: RetryPolicy,
                    key: str = "",
                    on_retry: Optional[Callable] = None,
                    sleep: Callable[[float], None] = time.sleep, **kw):
    """Call ``fn``; on a retryable exception back off and retry up to
    ``policy.max_attempts`` total attempts. Non-retryable exceptions and
    the final failure propagate unchanged. ``on_retry(attempt, exc)`` is
    invoked before each backoff (metrics hook)."""
    attempt = 0
    while True:
        try:
            return fn(*args, **kw)
        except BaseException as e:                   # noqa: BLE001
            if not is_retryable(e) or attempt >= policy.max_attempts - 1:
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            sleep(policy.backoff_s(attempt, key))
            attempt += 1
