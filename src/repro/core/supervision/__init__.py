"""Supervision layer: replica fleet registry + heartbeat recovery,
error taxonomy (retryable vs fatal), bounded retry with deterministic
backoff, and config-driven deterministic fault injection."""
from repro.core.supervision.errors import (ReplicaCrash, RetryableError,
                                           SupervisionExhausted,
                                           TransientStageError,
                                           WeightSyncTimeout, is_retryable,
                                           register_retryable)
from repro.core.supervision.faults import FaultConfig, FaultInjector
from repro.core.supervision.retry import RetryPolicy, call_with_retry
from repro.core.supervision.supervisor import ReplicaHandle, ReplicaSupervisor

__all__ = ["FaultConfig", "FaultInjector", "ReplicaCrash", "ReplicaHandle",
           "ReplicaSupervisor", "RetryPolicy", "RetryableError",
           "SupervisionExhausted", "TransientStageError",
           "WeightSyncTimeout", "call_with_retry", "is_retryable",
           "register_retryable"]
