"""Deterministic fault injection for chaos tests and benchmarks.

Every injection decision is a pure function of (seed, stage, worker,
call-ordinal): the Nth call a given (stage, worker) pair makes always
draws the same uniform, so a chaos run is exactly reproducible under a
fixed seed — the property the crash-recovery determinism tests assert.

The probability bands partition one uniform draw::

    [0, crash_p)                        -> ReplicaCrash
    [crash_p, crash_p + error_p)        -> TransientStageError
    [.., .. + delay_p)                  -> sleep(delay_s)
    otherwise                           -> no fault
"""
from __future__ import annotations

import hashlib
import threading
import time
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.core.obs import get_registry
from repro.core.supervision.errors import ReplicaCrash, TransientStageError

__all__ = ["FaultConfig", "FaultInjector"]


@dataclass(frozen=True)
class FaultConfig:
    """Crash/error/delay probabilities per stage call. ``stages`` limits
    injection to the named stages (empty = every stage); ``max_crashes``
    bounds total injected crashes (0 = unlimited) so a bounded restart
    budget cannot be exhausted by the injector itself.
    ``crash_on_calls`` schedules *deterministic* crashes at exact
    per-(stage, worker) call ordinals on top of the probability bands —
    how the trainer-kill arm murders the driver at a chosen mid-run
    step instead of hunting for a seed."""
    crash_p: float = 0.0
    error_p: float = 0.0
    delay_p: float = 0.0
    delay_s: float = 0.02
    seed: int = 0
    stages: Tuple[str, ...] = ()
    max_crashes: int = 0
    crash_on_calls: Tuple[int, ...] = ()

    @property
    def active(self) -> bool:
        return (self.crash_p + self.error_p + self.delay_p) > 0.0 \
            or bool(self.crash_on_calls)


class FaultInjector:
    """Config-driven deterministic chaos. Call :meth:`check` once per
    stage invocation; it raises (crash/error), sleeps (delay), or
    returns clean."""

    def __init__(self, cfg: FaultConfig, metrics=None,
                 sleep=time.sleep):
        self.cfg = cfg
        self._sleep = sleep
        self._lock = threading.Lock()
        self._calls: Dict[Tuple[str, int], int] = {}
        self._crashes = 0
        m = metrics if metrics is not None else get_registry()
        self._m_injected = m.counter(
            "faults_injected_total",
            "faults injected per stage and kind (crash | error | delay)")

    def _uniform(self, stage: str, worker: int, ordinal: int) -> float:
        key = f"{self.cfg.seed}:{stage}:{worker}:{ordinal}".encode()
        h = hashlib.blake2b(key, digest_size=8).digest()
        return int.from_bytes(h, "big") / 2.0 ** 64

    def crashes_injected(self) -> int:
        with self._lock:
            return self._crashes

    def check(self, stage: str, worker: int = 0) -> None:
        cfg = self.cfg
        if not cfg.active or (cfg.stages and stage not in cfg.stages):
            return
        with self._lock:
            ordinal = self._calls.get((stage, worker), 0)
            self._calls[(stage, worker)] = ordinal + 1
            u = self._uniform(stage, worker, ordinal)
            crash = ordinal in cfg.crash_on_calls or \
                (u < cfg.crash_p and
                 (cfg.max_crashes <= 0 or self._crashes < cfg.max_crashes))
            if crash:
                self._crashes += 1
        if crash:
            self._m_injected.inc(stage=stage, kind="crash")
            raise ReplicaCrash(
                f"injected crash (stage={stage}, worker={worker}, "
                f"call={ordinal})", replica=worker)
        if cfg.crash_p <= u < cfg.crash_p + cfg.error_p:
            self._m_injected.inc(stage=stage, kind="error")
            raise TransientStageError(
                f"injected transient error (stage={stage}, "
                f"worker={worker}, call={ordinal})")
        if cfg.crash_p + cfg.error_p <= u < \
                cfg.crash_p + cfg.error_p + cfg.delay_p:
            self._m_injected.inc(stage=stage, kind="delay")
            self._sleep(cfg.delay_s)
