"""Autoregressive rollout generation with a KV cache.

The rollout engine's inner loop: batched prompt feed (teacher-forced
decode steps, sharing the exact production serve path) followed by
temperature sampling of up to ``max_new_tokens``, collecting per-token
behavior logprobs — what the actor-update step needs as ``old_logprob``.

Fixed shapes throughout → a single XLA compilation per (B, cache_len).
"""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import ByteTokenizer
from repro.models import decode_step, init_cache


@functools.partial(jax.jit, static_argnames=("cfg", "max_new", "temperature"))
def _generate_jit(params, cfg, prompt_tokens, prompt_lens, rng, *,
                  max_new: int, temperature: float = 1.0):
    """prompt_tokens: (B, Lp) right-padded; prompt_lens: (B,).
    Returns (tokens (B, Lp+max_new), logprobs (B, Lp+max_new), resp_mask)."""
    B, Lp = prompt_tokens.shape
    total = Lp + max_new
    cache = init_cache(cfg, B, total)

    def step(carry, t):
        cache, cur_tok, rng, out_toks, out_lps = carry
        logits, cache = decode_step(params, cfg, cache, cur_tok,
                                    jnp.full((B,), t, jnp.int32))
        logits = logits.astype(jnp.float32) / max(temperature, 1e-6)
        logp = jax.nn.log_softmax(logits, axis=-1)
        rng, sub = jax.random.split(rng)
        sampled = jax.random.categorical(sub, logits)
        # during the prompt: next token is forced; after: sampled
        in_prompt = (t + 1) < prompt_lens
        forced = prompt_tokens[:, jnp.minimum(t + 1, Lp - 1)]
        nxt = jnp.where(in_prompt, forced, sampled)
        tok_lp = jnp.take_along_axis(logp, nxt[:, None], axis=1)[:, 0]
        out_toks = out_toks.at[:, t + 1].set(nxt)
        out_lps = out_lps.at[:, t + 1].set(tok_lp)
        return (cache, nxt, rng, out_toks, out_lps), None

    out_toks = jnp.zeros((B, total), jnp.int32)
    out_toks = out_toks.at[:, 0].set(prompt_tokens[:, 0])
    out_lps = jnp.zeros((B, total), jnp.float32)
    carry = (cache, prompt_tokens[:, 0], rng, out_toks, out_lps)
    (cache, _, _, out_toks, out_lps), _ = jax.lax.scan(
        step, carry, jnp.arange(total - 1))

    pos = jnp.arange(total)[None, :]
    resp_mask = (pos >= prompt_lens[:, None]).astype(jnp.float32)
    return out_toks, out_lps, resp_mask


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def generate(params, cfg, prompts: List[np.ndarray], rng_seed: int, *,
             max_new_tokens: int = 16, temperature: float = 1.0,
             eos_id: int = ByteTokenizer.eos_id,
             bucket: bool = True):
    """Returns list of dicts per prompt: tokens, logprobs, response_mask,
    response_ids (trimmed at EOS), prompt_len.

    bucket=True pads the batch dim to a power of two and the prompt length
    to a multiple of 8 so repeated calls reuse one XLA compilation
    (continuous-batching engines do the same bucketing)."""
    tok = ByteTokenizer()
    n_real = len(prompts)
    prompts = list(prompts)
    if bucket:
        target_b = _next_pow2(n_real)
        prompts += [prompts[-1]] * (target_b - n_real)
        max_len = max(len(p) for p in prompts)
        pad_len = ((max_len + 7) // 8) * 8
        toks, mask = tok.pad_batch(prompts, length=pad_len)
    else:
        toks, mask = tok.pad_batch(prompts)
    lens = np.asarray([len(p) for p in prompts], np.int32)
    out_toks, out_lps, resp_mask = _generate_jit(
        params, cfg, jnp.asarray(toks), jnp.asarray(lens),
        jax.random.PRNGKey(rng_seed), max_new=max_new_tokens,
        temperature=temperature)
    out_toks = np.asarray(out_toks)
    out_lps = np.asarray(out_lps)
    resp_mask = np.asarray(resp_mask)

    rows = []
    for i in range(n_real):
        lp_len = int(lens[i])
        resp = out_toks[i, lp_len:]
        cut = np.where(resp == eos_id)[0]
        n_resp = int(cut[0]) + 1 if len(cut) else len(resp)
        m = resp_mask[i].copy()
        m[lp_len + n_resp:] = 0.0
        rows.append(dict(tokens=out_toks[i], logprobs=out_lps[i],
                         response_mask=m, response_ids=resp[:n_resp],
                         prompt_len=lp_len))
    return rows
