"""GRPO actor-update step (the paper's evaluated RL algorithm, §6.1).

The jitted ``grpo_train_step`` is also what the train_4k dry-run lowers:
forward + clipped policy loss (+ optional KL-to-reference) + backward +
AdamW — the paper-representative training step.

``grpo_dataflow`` declares GRPO as a streaming stage graph (§3.3/§4.1):

    generate → [ref_inference] → reward/advantage → actor_update

Each task streams independently through one shared TransferQueue; group
advantages are emitted by the reward stage as deferred writes once every
member of a group has streamed through.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax

from repro.core.workflow.stage_graph import (StageGraph, StageSpec,
                                             register_dataflow)
from repro.models import forward
from repro.rl.loss import fused_actor_loss
from repro.training.optimizer import OptimizerConfig
from repro.training.train_state import TrainState


@dataclasses.dataclass(frozen=True)
class GRPOConfig:
    clip_eps: float = 0.2
    kl_coef: float = 0.0          # >0 adds KL-to-reference penalty
    entropy_coef: float = 0.0
    use_pallas_logprob: bool = False


def grpo_loss_fn(params, cfg, batch, rl: GRPOConfig,
                 ref_logprob=None):
    """batch:
      tokens (B, S)           — prompt + response (+pad)
      response_mask (B, S)    — 1 on response tokens (as *targets*)
      old_logprob (B, S)      — behavior-policy per-token logprobs
      advantage (B,)          — group-relative advantage per sample
      ref_logprob (B, S)      — optional frozen-reference logprobs (KL)
      extra model inputs (vision_embeds / frames) pass through.
    """
    if ref_logprob is None:
        ref_logprob = batch.get("ref_logprob")
    tokens = batch["tokens"]
    inputs = {k: v for k, v in batch.items()
              if k in ("tokens", "vision_embeds", "frames")}
    logits, aux = forward(params, cfg, inputs)
    # VLM prepends vision tokens; predictions for text targets are the
    # last S-1 text positions (same as pure LM after slicing the prefix)
    S = tokens.shape[1]
    logits = logits[:, -S:, :]
    mask = batch["response_mask"][:, 1:]

    # one fused pass over the (B, S, V) logits: logprob + entropy + KL +
    # clipped surrogate, hand-written VJP (kernels/fused_rl_loss)
    actor_loss, stats = fused_actor_loss(
        logits[:, :-1], tokens[:, 1:], batch["old_logprob"][:, 1:],
        batch["advantage"], mask,
        ref_logprob=ref_logprob[:, 1:] if ref_logprob is not None else None,
        clip_eps=rl.clip_eps, kl_coef=rl.kl_coef,
        entropy_coef=rl.entropy_coef, use_pallas=rl.use_pallas_logprob)
    loss = actor_loss + aux
    metrics = {"loss": loss, **stats}
    return loss, metrics


@functools.partial(jax.jit, static_argnames=("cfg", "rl", "opt_cfg"))
def grpo_train_step(state: TrainState, cfg, rl: GRPOConfig,
                    opt_cfg: OptimizerConfig, batch):
    """One jitted GRPO update. Returns (new_state, metrics)."""
    (_, metrics), grads = jax.value_and_grad(grpo_loss_fn, has_aux=True)(
        state.params, cfg, batch, rl)
    new_state, gnorm = state.apply_gradients(grads, opt_cfg)
    metrics["grad_norm"] = gnorm
    return new_state, metrics


def grpo_grad_step(params, cfg, rl: GRPOConfig, batch):
    """Gradients only (for streaming gradient accumulation)."""
    (_, metrics), grads = jax.value_and_grad(grpo_loss_fn, has_aux=True)(
        params, cfg, batch, rl)
    return grads, metrics


def grpo_dataflow(*, kl_coef: float = 0.0, **_) -> StageGraph:
    """GRPO as a streaming stage graph (see module docstring). With
    ``kl_coef > 0`` the frozen-reference inference runs as its own
    streaming task between generation and the actor update."""
    g = StageGraph(source_columns=("prompt",))
    g.add(StageSpec("generate", inputs=("prompt",),
                    outputs=("response", "logprob", "response_mask",
                             "response_ids", "group", "answer", "version"),
                    engine="rollout", verb="generate_sequences",
                    kind="generate"))
    if kl_coef > 0:
        g.add(StageSpec("ref_inference", inputs=("response",),
                        outputs=("ref_logprob",),
                        engine="rollout", verb="compute_log_prob"))
    g.add(StageSpec("reward", inputs=("response_ids", "answer", "group"),
                    outputs=("reward", "advantage"),
                    engine="rollout", verb="compute_rewards"))
    train_in = ["response", "logprob", "response_mask", "reward",
                "advantage", "version"]
    if kl_coef > 0:
        train_in.append("ref_logprob")
    g.add(StageSpec("actor_update", inputs=tuple(train_in),
                    engine="actor", verb="update_actor",
                    kind="train", drives_steps=True))
    return g


register_dataflow("grpo", grpo_dataflow)
