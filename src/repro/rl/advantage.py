"""Advantage estimators: GRPO group-relative and GAE (for PPO)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def grpo_advantages(rewards, eps: float = 1e-6):
    """Group-relative advantages (GRPO): rewards (G,) for one prompt's G
    responses -> (r - mean) / (std + eps). Works on np or jnp arrays."""
    xp = jnp if isinstance(rewards, jnp.ndarray) else np
    r = xp.asarray(rewards, dtype=xp.float32)
    mu = r.mean()
    sd = r.std()
    return (r - mu) / (sd + eps)


def gae(rewards, values, *, gamma: float = 1.0, lam: float = 0.95):
    """Generalized advantage estimation over a (T,) trajectory.
    values has length T+1 (bootstrap). Returns (advantages, returns)."""
    rewards = np.asarray(rewards, np.float32)
    values = np.asarray(values, np.float32)
    T = len(rewards)
    adv = np.zeros(T, np.float32)
    last = 0.0
    for t in reversed(range(T)):
        delta = rewards[t] + gamma * values[t + 1] - values[t]
        last = delta + gamma * lam * last
        adv[t] = last
    return adv, adv + values[:-1]
