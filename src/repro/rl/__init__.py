from repro.rl.advantage import gae, grpo_advantages
from repro.rl.grpo import (GRPOConfig, grpo_dataflow, grpo_grad_step,
                           grpo_loss_fn, grpo_train_step)
from repro.rl.loss import (clipped_policy_loss, fused_actor_loss, kl_penalty,
                           token_logprobs, value_loss)
from repro.rl.ppo import (PPOConfig, critic_forward, gae_stage,
                          init_critic_params, ppo_actor_loss_fn,
                          ppo_critic_loss_fn, ppo_dataflow, ppo_loss_fn,
                          ppo_train_step)
from repro.rl.reward import math_reward
from repro.rl.sampling import generate

__all__ = ["grpo_advantages", "gae", "GRPOConfig", "grpo_train_step",
           "grpo_grad_step", "grpo_loss_fn", "grpo_dataflow", "PPOConfig",
           "ppo_train_step", "ppo_loss_fn", "ppo_actor_loss_fn",
           "ppo_critic_loss_fn", "ppo_dataflow", "gae_stage",
           "init_critic_params", "critic_forward", "math_reward",
           "generate", "token_logprobs", "clipped_policy_loss",
           "fused_actor_loss", "kl_penalty", "value_loss"]
