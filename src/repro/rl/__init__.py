from repro.rl.advantage import gae, grpo_advantages
from repro.rl.grpo import GRPOConfig, grpo_grad_step, grpo_loss_fn, \
    grpo_train_step
from repro.rl.loss import (clipped_policy_loss, kl_penalty, token_logprobs,
                           value_loss)
from repro.rl.ppo import PPOConfig, critic_forward, init_critic_params, \
    ppo_loss_fn, ppo_train_step
from repro.rl.reward import math_reward
from repro.rl.sampling import generate

__all__ = ["grpo_advantages", "gae", "GRPOConfig", "grpo_train_step",
           "grpo_grad_step", "grpo_loss_fn", "PPOConfig", "ppo_train_step",
           "ppo_loss_fn", "init_critic_params", "critic_forward",
           "math_reward", "generate", "token_logprobs",
           "clipped_policy_loss", "kl_penalty", "value_loss"]
