"""PPO actor + critic update ("under development" in the paper §6.1 —
completed here). The critic is a value head over the same backbone
trunk; reference/reward models plug in as additional RL tasks through
TransferQueue exactly like the GRPO flow."""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models import forward, init_params
from repro.models.layers import dense, init_dense, normal_init
from repro.rl.loss import (clipped_policy_loss, kl_penalty, token_logprobs,
                           value_loss)
from repro.training.optimizer import OptimizerConfig
from repro.training.train_state import TrainState


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    clip_eps: float = 0.2
    value_clip_eps: float = 0.2
    vf_coef: float = 0.5
    kl_coef: float = 0.0
    entropy_coef: float = 0.0
    use_pallas_logprob: bool = False


def init_critic_params(key, cfg):
    """Critic = backbone + scalar value head."""
    k1, k2 = jax.random.split(key)
    return {"backbone": init_params(k1, cfg),
            "value_head": init_dense(k2, cfg.d_model, 1)}


def critic_forward(critic, cfg, tokens):
    """Per-token values (B, S): value head over the backbone's final-norm
    hidden states."""
    from repro.models import transformer
    hidden = transformer.forward_hidden(critic["backbone"], cfg, tokens)
    v = dense(critic["value_head"], hidden, hidden.dtype)
    return v[..., 0].astype(jnp.float32)


def ppo_loss_fn(actor_params, critic_params, cfg, batch, rl: PPOConfig):
    """batch: tokens, response_mask, old_logprob, advantage (B,S),
    returns (B,S), old_values (B,S), optional ref_logprob."""
    tokens = batch["tokens"]
    logits, aux = forward(actor_params, cfg, {"tokens": tokens})
    logp, ent = token_logprobs(logits[:, :-1], tokens[:, 1:],
                               use_pallas=rl.use_pallas_logprob)
    mask = batch["response_mask"][:, 1:]
    pl_loss, stats = clipped_policy_loss(
        logp, batch["old_logprob"][:, 1:], batch["advantage"][:, 1:], mask,
        clip_eps=rl.clip_eps)

    values = critic_forward(critic_params, cfg, tokens)[:, :-1]
    vf = value_loss(values, batch["returns"][:, 1:],
                    batch["old_values"][:, 1:], mask,
                    clip_eps=rl.value_clip_eps)
    loss = pl_loss + rl.vf_coef * vf + aux
    if rl.kl_coef and "ref_logprob" in batch:
        loss = loss + rl.kl_coef * kl_penalty(
            logp, batch["ref_logprob"][:, 1:], mask)
    if rl.entropy_coef:
        loss = loss - rl.entropy_coef * (ent * mask).sum() / \
            jnp.maximum(mask.sum(), 1.0)
    return loss, {"loss": loss, "policy_loss": pl_loss, "value_loss": vf,
                  **stats}


@functools.partial(jax.jit, static_argnames=("cfg", "rl", "opt_cfg"))
def ppo_train_step(actor_state: TrainState, critic_state: TrainState,
                   cfg, rl: PPOConfig, opt_cfg: OptimizerConfig, batch):
    def actor_loss(p):
        return ppo_loss_fn(p, critic_state.params, cfg, batch, rl)

    (_, metrics), a_grads = jax.value_and_grad(actor_loss, has_aux=True)(
        actor_state.params)

    def critic_loss(p):
        tokens = batch["tokens"]
        values = critic_forward(p, cfg, tokens)[:, :-1]
        mask = batch["response_mask"][:, 1:]
        return value_loss(values, batch["returns"][:, 1:],
                          batch["old_values"][:, 1:], mask,
                          clip_eps=rl.value_clip_eps)

    c_grads = jax.grad(critic_loss)(critic_state.params)
    new_actor, agn = actor_state.apply_gradients(a_grads, opt_cfg)
    new_critic, cgn = critic_state.apply_gradients(c_grads, opt_cfg)
    metrics.update(actor_grad_norm=agn, critic_grad_norm=cgn)
    return new_actor, new_critic, metrics
