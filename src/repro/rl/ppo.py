"""PPO actor + critic update ("under development" in the paper §6.1 —
completed here). The critic is a value head over the same backbone
trunk; reference/reward models plug in as additional RL tasks through
TransferQueue exactly like the GRPO flow.

``ppo_dataflow`` declares PPO as a streaming stage graph (§3.3/§4.1):

    generate → [ref_inference] → values → reward → advantage(GAE)
             → actor_update + critic_update

Each task streams independently through one shared TransferQueue; the
actor update drives training steps and weight publication while the
critic update streams alongside as its own consumer (``train_stream``).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.workflow.stage_graph import (StageGraph, StageSpec,
                                             register_dataflow)
from repro.models import forward, init_params
from repro.models.layers import dense, init_dense, normal_init
from repro.rl.advantage import gae
from repro.rl.loss import fused_actor_loss, value_loss
from repro.training.optimizer import OptimizerConfig
from repro.training.train_state import TrainState


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    clip_eps: float = 0.2
    value_clip_eps: float = 0.2
    vf_coef: float = 0.5
    kl_coef: float = 0.0
    entropy_coef: float = 0.0
    use_pallas_logprob: bool = False


def init_critic_params(key, cfg):
    """Critic = backbone + scalar value head."""
    k1, k2 = jax.random.split(key)
    return {"backbone": init_params(k1, cfg),
            "value_head": init_dense(k2, cfg.d_model, 1)}


def critic_forward(critic, cfg, tokens):
    """Per-token values (B, S): value head over the backbone's final-norm
    hidden states."""
    from repro.models import transformer
    hidden = transformer.forward_hidden(critic["backbone"], cfg, tokens)
    v = dense(critic["value_head"], hidden, hidden.dtype)
    return v[..., 0].astype(jnp.float32)


def ppo_loss_fn(actor_params, critic_params, cfg, batch, rl: PPOConfig):
    """batch: tokens, response_mask, old_logprob, advantage (B,S),
    returns (B,S), old_values (B,S), optional ref_logprob."""
    tokens = batch["tokens"]
    logits, aux = forward(actor_params, cfg, {"tokens": tokens})
    mask = batch["response_mask"][:, 1:]
    ref_lp = batch.get("ref_logprob")
    actor_loss, stats = fused_actor_loss(
        logits[:, :-1], tokens[:, 1:], batch["old_logprob"][:, 1:],
        batch["advantage"][:, 1:], mask,
        ref_logprob=ref_lp[:, 1:] if ref_lp is not None else None,
        clip_eps=rl.clip_eps, kl_coef=rl.kl_coef,
        entropy_coef=rl.entropy_coef, use_pallas=rl.use_pallas_logprob)

    values = critic_forward(critic_params, cfg, tokens)[:, :-1]
    vf = value_loss(values, batch["returns"][:, 1:],
                    batch["old_values"][:, 1:], mask,
                    clip_eps=rl.value_clip_eps)
    loss = actor_loss + rl.vf_coef * vf + aux
    return loss, {"loss": loss, "value_loss": vf, **stats}


def ppo_actor_loss_fn(params, cfg, batch, rl: PPOConfig):
    """Actor-only PPO loss for the ``actor_update`` stage: clipped policy
    objective over per-token GAE advantages (+ optional KL / entropy).
    The value term lives in the separate ``critic_update`` stage."""
    tokens = batch["tokens"]
    logits, aux = forward(params, cfg, {"tokens": tokens})
    mask = batch["response_mask"][:, 1:]
    ref_lp = batch.get("ref_logprob")
    actor_loss, stats = fused_actor_loss(
        logits[:, :-1], tokens[:, 1:], batch["old_logprob"][:, 1:],
        batch["advantage"][:, 1:], mask,
        ref_logprob=ref_lp[:, 1:] if ref_lp is not None else None,
        clip_eps=rl.clip_eps, kl_coef=rl.kl_coef,
        entropy_coef=rl.entropy_coef, use_pallas=rl.use_pallas_logprob)
    loss = actor_loss + aux
    metrics = {"loss": loss, **stats}
    return loss, metrics


def ppo_critic_loss_fn(critic_params, cfg, batch, rl: PPOConfig):
    """Critic-only PPO loss for the ``critic_update`` stage."""
    values = critic_forward(critic_params, cfg, batch["tokens"])[:, :-1]
    mask = batch["response_mask"][:, 1:]
    vf = value_loss(values, batch["returns"][:, 1:],
                    batch["old_values"][:, 1:], mask,
                    clip_eps=rl.value_clip_eps)
    return vf, {"value_loss": vf}


def gae_stage(batch, *, gamma: float = 1.0, lam: float = 0.95, **kw):
    """Stage fn for the ``advantage`` task: per-token GAE advantages and
    returns from streamed reward + critic values (terminal reward on the
    last response token, as in the verifiable-reward setting)."""
    advs, rets = [], []
    for mask, reward, values in zip(batch["response_mask"], batch["reward"],
                                    batch["values"]):
        mask = np.asarray(mask)
        v = np.asarray(values, np.float32)
        adv = np.zeros(len(mask), np.float32)
        ret = np.zeros(len(mask), np.float32)
        idx = np.where(mask > 0)[0]
        if len(idx):
            traj_r = np.zeros(len(idx), np.float32)
            traj_r[-1] = float(reward)
            vv = np.concatenate([v[idx], [0.0]])
            a, r = gae(traj_r, vv, gamma=gamma, lam=lam)
            adv[idx] = a
            ret[idx] = r
        advs.append(adv)
        rets.append(ret)
    # returns before advantage: the actor update gates on "advantage", so
    # by the time the step driver can consume a row (and end the run) the
    # critic's "returns" column is already written — the critic_update
    # drain after shutdown then sees every row
    return {"updates": {"returns": rets, "advantage": advs}}


def ppo_dataflow(*, kl_coef: float = 0.0, gamma: float = 1.0,
                 lam: float = 0.95, **_) -> StageGraph:
    """PPO as a streaming stage graph (see module docstring)."""
    g = StageGraph(source_columns=("prompt",))
    g.add(StageSpec("generate", inputs=("prompt",),
                    outputs=("response", "logprob", "response_mask",
                             "response_ids", "group", "answer", "version"),
                    engine="rollout", verb="generate_sequences",
                    kind="generate"))
    if kl_coef > 0:
        g.add(StageSpec("ref_inference", inputs=("response",),
                        outputs=("ref_logprob",),
                        engine="rollout", verb="compute_log_prob"))
    g.add(StageSpec("values", inputs=("response",), outputs=("values",),
                    engine="critic", verb="compute_values"))
    g.add(StageSpec("reward", inputs=("response_ids", "answer", "group"),
                    outputs=("reward",),
                    engine="rollout", verb="compute_rewards",
                    kw={"group_advantage": False}))
    g.add(StageSpec("advantage",
                    inputs=("response_mask", "reward", "values"),
                    outputs=("advantage", "returns"),
                    fn=gae_stage, kw={"gamma": gamma, "lam": lam}))
    actor_in = ["response", "logprob", "response_mask", "reward",
                "advantage", "version"]
    if kl_coef > 0:
        actor_in.append("ref_logprob")
    g.add(StageSpec("actor_update", inputs=tuple(actor_in),
                    engine="actor", verb="update_actor",
                    kind="train", drives_steps=True))
    g.add(StageSpec("critic_update",
                    inputs=("response", "response_mask", "returns",
                            "values", "version"),
                    engine="critic", verb="update_critic",
                    kind="train_stream"))
    return g


register_dataflow("ppo", ppo_dataflow)


@functools.partial(jax.jit, static_argnames=("cfg", "rl", "opt_cfg"))
def ppo_train_step(actor_state: TrainState, critic_state: TrainState,
                   cfg, rl: PPOConfig, opt_cfg: OptimizerConfig, batch):
    def actor_loss(p):
        return ppo_loss_fn(p, critic_state.params, cfg, batch, rl)

    (_, metrics), a_grads = jax.value_and_grad(actor_loss, has_aux=True)(
        actor_state.params)

    def critic_loss(p):
        tokens = batch["tokens"]
        values = critic_forward(p, cfg, tokens)[:, :-1]
        mask = batch["response_mask"][:, 1:]
        return value_loss(values, batch["returns"][:, 1:],
                          batch["old_values"][:, 1:], mask,
                          clip_eps=rl.value_clip_eps)

    c_grads = jax.grad(critic_loss)(critic_state.params)
    new_actor, agn = actor_state.apply_gradients(a_grads, opt_cfg)
    new_critic, cgn = critic_state.apply_gradients(c_grads, opt_cfg)
    metrics.update(actor_grad_norm=agn, critic_grad_norm=cgn)
    return new_actor, new_critic, metrics
