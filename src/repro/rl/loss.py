"""Policy-gradient losses (GRPO / PPO) with the fused RL hot-path kernel.

All losses are masked to response tokens. The actor update goes through
``fused_actor_loss``, which routes the entire per-token hot path —
logprob + entropy + k3 KL + clipped surrogate — through
``kernels/fused_rl_loss``: ONE streamed pass over the (B, S, V) logits
forward and one backward (hand-written VJP recomputing softmax from
per-token statistics), instead of the three-op composition below that
materializes log-softmax plus its autodiff residual. The unfused
primitives (``token_logprobs``/``clipped_policy_loss``/``kl_penalty``)
remain for inference-side logprobs, tests and benchmarks; ``value_loss``
stays separate for the critic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def token_logprobs(logits, targets, use_pallas: bool = False):
    """logits: (B, S, V) for predicting targets (B, S).
    Returns (logprob (B,S) f32, entropy (B,S) f32)."""
    if use_pallas:
        from repro.kernels.grpo_logprob.ops import grpo_logprob
        return grpo_logprob(logits, targets)
    from repro.kernels.grpo_logprob.ref import grpo_logprob_ref
    V = logits.shape[-1]
    lp, ent = grpo_logprob_ref(logits.reshape(-1, V), targets.reshape(-1))
    return lp.reshape(targets.shape), ent.reshape(targets.shape)


def clipped_policy_loss(logp_new, logp_old, advantages, mask, *,
                        clip_eps: float = 0.2):
    """PPO/GRPO clipped surrogate.

    logp_new/logp_old: (B, S) per-token; advantages: (B,) per sample
    (GRPO) or (B, S) per token (PPO+GAE); mask: (B, S) response mask.
    """
    if advantages.ndim == 1:
        advantages = advantages[:, None]
    ratio = jnp.exp(logp_new - logp_old)
    unclipped = ratio * advantages
    clipped = jnp.clip(ratio, 1 - clip_eps, 1 + clip_eps) * advantages
    per_tok = -jnp.minimum(unclipped, clipped)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (per_tok * mask).sum() / denom
    clip_frac = ((jnp.abs(ratio - 1) > clip_eps) * mask).sum() / denom
    return loss, {"ratio_mean": (ratio * mask).sum() / denom,
                  "clip_frac": clip_frac}


def fused_actor_loss(logits, targets, old_logprob, advantages, mask, *,
                     ref_logprob=None, clip_eps: float = 0.2,
                     kl_coef: float = 0.0, entropy_coef: float = 0.0,
                     use_pallas: bool = False):
    """The GRPO/PPO actor objective in one fused pass over the logits.

    logits (B, S, V) predicting targets (B, S); old_logprob (B, S);
    advantages (B,) per sample (GRPO) or (B, S) per token (PPO+GAE);
    mask (B, S). Returns ``(loss, stats)`` with the same masked-mean
    semantics and stat keys as the unfused composition.
    """
    from repro.kernels.fused_rl_loss.ops import fused_rl_loss
    if advantages.ndim == 1:
        advantages = jnp.broadcast_to(advantages[:, None], targets.shape)
    use_kl = bool(kl_coef) and ref_logprob is not None
    ref = ref_logprob if use_kl else jnp.zeros_like(old_logprob)
    lp, ent, kl, pl_tok, ratio = fused_rl_loss(
        logits, targets, old_logprob, ref, advantages,
        clip_eps=clip_eps, use_pallas=use_pallas)
    denom = jnp.maximum(mask.sum(), 1.0)
    pl_loss = (pl_tok * mask).sum() / denom
    ent_mean = (ent * mask).sum() / denom
    loss = pl_loss
    if use_kl:
        loss = loss + kl_coef * (kl * mask).sum() / denom
    if entropy_coef:
        loss = loss - entropy_coef * ent_mean
    stats = {"policy_loss": pl_loss, "entropy": ent_mean,
             "ratio_mean": (ratio * mask).sum() / denom,
             "clip_frac": ((jnp.abs(ratio - 1) > clip_eps)
                           * mask).sum() / denom}
    return loss, stats


def kl_penalty(logp_new, logp_ref, mask):
    """k3 estimator (Schulman): exp(ref-new) - (ref-new) - 1 >= 0."""
    d = logp_ref - logp_new
    k3 = jnp.exp(d) - d - 1.0
    return (k3 * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def value_loss(values, returns, old_values, mask, *, clip_eps: float = 0.2):
    """Clipped value loss (PPO critic)."""
    v_clip = old_values + jnp.clip(values - old_values, -clip_eps, clip_eps)
    l1 = jnp.square(values - returns)
    l2 = jnp.square(v_clip - returns)
    return 0.5 * (jnp.maximum(l1, l2) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
