"""Policy-gradient losses (GRPO / PPO) with the fused logprob kernel.

All losses are masked to response tokens; logits-side computation goes
through ``token_logprobs`` which can use the Pallas ``grpo_logprob``
kernel (the memory-bound hotspot over 100k-256k vocab logits).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def token_logprobs(logits, targets, use_pallas: bool = False):
    """logits: (B, S, V) for predicting targets (B, S).
    Returns (logprob (B,S) f32, entropy (B,S) f32)."""
    if use_pallas:
        from repro.kernels.grpo_logprob.ops import grpo_logprob
        return grpo_logprob(logits, targets)
    from repro.kernels.grpo_logprob.ref import grpo_logprob_ref
    V = logits.shape[-1]
    lp, ent = grpo_logprob_ref(logits.reshape(-1, V), targets.reshape(-1))
    return lp.reshape(targets.shape), ent.reshape(targets.shape)


def clipped_policy_loss(logp_new, logp_old, advantages, mask, *,
                        clip_eps: float = 0.2):
    """PPO/GRPO clipped surrogate.

    logp_new/logp_old: (B, S) per-token; advantages: (B,) per sample
    (GRPO) or (B, S) per token (PPO+GAE); mask: (B, S) response mask.
    """
    if advantages.ndim == 1:
        advantages = advantages[:, None]
    ratio = jnp.exp(logp_new - logp_old)
    unclipped = ratio * advantages
    clipped = jnp.clip(ratio, 1 - clip_eps, 1 + clip_eps) * advantages
    per_tok = -jnp.minimum(unclipped, clipped)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (per_tok * mask).sum() / denom
    clip_frac = ((jnp.abs(ratio - 1) > clip_eps) * mask).sum() / denom
    return loss, {"ratio_mean": (ratio * mask).sum() / denom,
                  "clip_frac": clip_frac}


def kl_penalty(logp_new, logp_ref, mask):
    """k3 estimator (Schulman): exp(ref-new) - (ref-new) - 1 >= 0."""
    d = logp_ref - logp_new
    k3 = jnp.exp(d) - d - 1.0
    return (k3 * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def value_loss(values, returns, old_values, mask, *, clip_eps: float = 0.2):
    """Clipped value loss (PPO critic)."""
    v_clip = old_values + jnp.clip(values - old_values, -clip_eps, clip_eps)
    l1 = jnp.square(values - returns)
    l2 = jnp.square(v_clip - returns)
    return 0.5 * (jnp.maximum(l1, l2) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
