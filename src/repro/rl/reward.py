"""Rule-based verifiable reward (DeepScaleR-style answer checking)."""
from __future__ import annotations

import re

from repro.data.tokenizer import ByteTokenizer

_tok = ByteTokenizer()


def math_reward(answer: int, response_ids) -> float:
    """+1 exact integer match, +0.2 if the answer appears anywhere,
    -0.1 otherwise (mild penalty keeps logits moving early on)."""
    text = _tok.decode(response_ids)
    m = re.match(r"\s*(-?\d+)", text)
    if m is not None and int(m.group(1)) == answer:
        return 1.0
    if re.search(rf"(?<!\d)-?{abs(answer)}(?!\d)", text):
        return 0.2
    return -0.1


def math_reward_shaped(answer: int, response_ids) -> float:
    """Dense-signal variant for small-scale runs: exact match 1.0, else
    partial credit for digit density and answer presence. The GRPO group
    advantage needs within-group reward variance to produce gradient; the
    shaped reward provides it from step 0 (used by the Fig.-12 stability
    benchmark — both sync and async modes use the same reward, so the
    comparison is unaffected)."""
    text = _tok.decode(response_ids)
    m = re.match(r"\s*(-?\d+)", text)
    if m is not None and int(m.group(1)) == answer:
        return 1.0
    r = -0.1
    if text:
        digit_frac = sum(c.isdigit() for c in text) / len(text)
        r += 0.4 * digit_frac
    if re.search(rf"(?<!\d)-?{abs(answer)}(?!\d)", text):
        r += 0.3
    return r


def length_penalty(response_len: int, max_len: int, coef: float = 0.0) -> float:
    return -coef * max(0, response_len - max_len) / max(1, max_len)
