"""Unified model facade — every assigned architecture behind one API.

    params = init_params(key, cfg)
    logits, aux = forward(params, cfg, batch)              # train/prefill
    logits, cache = decode_step(params, cfg, cache, token, pos)

``batch`` is a dict: tokens (B,S) plus modality extras
(``frames`` for audio, ``vision_embeds`` for vlm).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.layers import dtype_of


def init_params(key, cfg):
    if cfg.arch_type == "audio":
        return encdec.init_encdec(key, cfg)
    return transformer.init_lm(key, cfg)


def forward(params, cfg, batch, *, window=0, use_pallas=False,
            return_cache=False):
    """Full-sequence forward. Returns (logits, aux[, cache])."""
    tokens = batch["tokens"]
    if cfg.arch_type == "audio":
        memory = encdec.encode(params, cfg, batch["frames"])
        logits, aux, cache = encdec.decode_train(params, cfg, memory, tokens)
    elif cfg.arch_type == "vlm":
        logits, aux, cache = transformer.forward_lm(
            params, cfg, tokens, extra_embeds=batch.get("vision_embeds"),
            window=window, use_pallas=use_pallas, return_cache=return_cache)
    else:
        logits, aux, cache = transformer.forward_lm(
            params, cfg, tokens, window=window, use_pallas=use_pallas,
            return_cache=return_cache)
    if return_cache:
        return logits, aux, cache
    return logits, aux


def init_cache(cfg, batch_size, length, dtype=jnp.bfloat16):
    if cfg.arch_type == "audio":
        return encdec.init_dec_cache(cfg, batch_size, length, dtype)
    return transformer.init_cache(cfg, batch_size, length, dtype)


def decode_step(params, cfg, cache, token, pos, *, ring=False,
                use_pallas=False, mesh=None):
    """One-token decode. token/pos: (B,). Returns (logits (B,V), cache).
    use_pallas → kernels/decode_attention; mesh → distributed sharded
    flash-decode (dense/moe GQA only)."""
    if cfg.arch_type == "audio":
        return encdec.decode_step(params, cfg, cache, token, pos)
    return transformer.decode_lm(params, cfg, cache, token, pos, ring=ring,
                                 use_pallas=use_pallas, mesh=mesh)


def decode_window(cfg, shape_name: str) -> tuple[int, bool]:
    """(cache length, ring?) policy for a decode input shape.

    long_500k on dense archs uses the sliding-window variant
    (cfg.long_context_window ring buffer) — see DESIGN.md §4.
    """
    from repro.configs.base import INPUT_SHAPES
    shp = INPUT_SHAPES[shape_name]
    if cfg.arch_type == "ssm":
        return 1, False  # state caches carry no seq dim; length unused
    if shp.name == "long_500k" and cfg.arch_type not in ("hybrid",):
        return cfg.long_context_window, True
    return shp.seq_len, False


def count_params(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
