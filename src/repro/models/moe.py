"""Mixture-of-Experts FFN with top-k routing.

Dispatch is sort-based with per-expert capacity buffers (no (N,E,C) one-hot
— that would be O(N·E·C) memory). Under GSPMD the expert buffer is
annotated so that:
  * ``num_experts % model_axis == 0`` → experts sharded over "model"
    (expert parallelism; XLA inserts the all-to-all-equivalent collectives);
  * otherwise → expert FFN hidden dim sharded over "model" (tensor-parallel
    experts, Megatron-style), buffer sharded over "data".

A router load-balance auxiliary loss (Switch-style) is returned alongside.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import act_fn, dense, init_dense, init_mlp, mlp


def init_moe(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    d, E, dff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    mult = 3 if cfg.activation == "silu" else 2
    kw = jax.random.split(ks[0], mult)
    experts = {
        "up": (0.02 * jax.random.normal(kw[0], (E, d, dff))).astype(dtype),
        "down": (0.02 * jax.random.normal(kw[1], (E, dff, d))).astype(dtype),
    }
    if mult == 3:
        experts["gate"] = (0.02 * jax.random.normal(kw[2], (E, d, dff))).astype(dtype)
    p = {"router": init_dense(ks[1], d, E, dtype=dtype), "experts": experts}
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[2], d, cfg.num_shared_experts * cfg.moe_d_ff,
                               cfg.activation, dtype=dtype)
    return p


def _expert_ffn(experts, buf, activation, cd):
    """buf: (E, C, d) -> (E, C, d)."""
    f = act_fn(activation)
    h = jnp.einsum("ecd,edf->ecf", buf, experts["up"].astype(cd))
    if "gate" in experts:
        h = h * f(jnp.einsum("ecd,edf->ecf", buf, experts["gate"].astype(cd)))
    else:
        h = f(h)
    return jnp.einsum("ecf,efd->ecd", h, experts["down"].astype(cd))


def moe_ffn(p, x, cfg, *, capacity_factor=1.25, shard_experts=None):
    """x: (B, S, d) -> (y, aux_loss).

    shard_experts: optional callable applied to the (E, C, d) buffers to add
    a sharding constraint (wired in repro.distributed.sharding).
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    cd = x.dtype
    N = B * S
    xf = x.reshape(N, d)

    logits = dense(p["router"], xf, cd).astype(jnp.float32)     # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)

    if cfg.moe_device_limit and cfg.num_experts % cfg.moe_ep_degree == 0 \
            and cfg.moe_device_limit < cfg.moe_ep_degree:
        # device-limited routing (DeepSeek-V2 §2.1.2, our §Perf HC4):
        # each token may select experts from at most M device groups,
        # bounding its all-to-all fan-out to M instead of top_k.
        G = cfg.moe_ep_degree
        epg = cfg.num_experts // G
        group_score = probs.reshape(N, G, epg).max(-1)          # (N, G)
        _, top_groups = jax.lax.top_k(group_score, cfg.moe_device_limit)
        group_mask = jnp.zeros((N, G), bool).at[
            jnp.arange(N)[:, None], top_groups].set(True)
        probs = jnp.where(
            jnp.repeat(group_mask, epg, axis=1), probs, 0.0)

    gate_vals, expert_ids = jax.lax.top_k(probs, k)             # (N, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # Switch-style load-balance aux loss.
    me = probs.mean(0)                                          # (E,)
    ce = jnp.zeros(E).at[expert_ids.reshape(-1)].add(1.0) / (N * k)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_coef

    # ---- sort-based dispatch --------------------------------------------
    C = int(max(1, round(N * k / E * capacity_factor)))
    flat_ids = expert_ids.reshape(-1)                           # (N*k,)
    order = jnp.argsort(flat_ids)                               # stable
    sorted_ids = flat_ids[order]
    starts = jnp.searchsorted(sorted_ids, jnp.arange(E))        # (E,)
    pos_in_expert = jnp.arange(N * k) - starts[sorted_ids]
    keep = pos_in_expert < C

    token_of = order // k                                       # source token
    buf = jnp.zeros((E, C, d), cd)
    buf = buf.at[sorted_ids, jnp.where(keep, pos_in_expert, 0)].add(
        jnp.where(keep[:, None], xf[token_of], jnp.zeros((), cd)))
    if shard_experts is not None:
        buf = shard_experts(buf)

    out_buf = _expert_ffn(p["experts"], buf, cfg.activation, cd)
    if shard_experts is not None:
        out_buf = shard_experts(out_buf)

    # ---- combine ----------------------------------------------------------
    gathered = out_buf[sorted_ids, jnp.where(keep, pos_in_expert, 0)]
    gathered = jnp.where(keep[:, None], gathered, jnp.zeros((), cd))
    w = gate_vals.reshape(-1)[order][:, None].astype(cd)
    y = jnp.zeros((N, d), cd).at[token_of].add(gathered * w)

    if "shared" in p:
        y = y + mlp(p["shared"], xf, cfg.activation, cd)
    return y.reshape(B, S, d), aux


@dataclasses.dataclass
class MoEStats:
    """Router statistics for load-balance monitoring (paper §3.3 load
    balancing feeds on per-DP-group token counts)."""
    tokens_per_expert: jnp.ndarray
    dropped_fraction: jnp.ndarray


def moe_router_stats(p, x, cfg, capacity_factor=1.25) -> MoEStats:
    B, S, d = x.shape
    N, E, k = B * S, cfg.num_experts, cfg.top_k
    logits = dense(p["router"], x.reshape(N, d), x.dtype).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    _, expert_ids = jax.lax.top_k(probs, k)
    counts = jnp.zeros(E).at[expert_ids.reshape(-1)].add(1.0)
    C = int(max(1, round(N * k / E * capacity_factor)))
    dropped = jnp.maximum(counts - C, 0.0).sum() / (N * k)
    return MoEStats(counts, dropped)
