"""Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3).

KV is compressed into a per-token latent ``c_kv`` (kv_lora_rank) plus a
shared rope key; the cache stores only the latent — this is what makes the
decode_32k / long_500k shapes feasible for these architectures.

Decode uses the *absorbed* formulation: W_uk is folded into the query and
W_uv applied after attention, so per-step cost is O(S · r) instead of
O(S · nh · hd) and the expanded K/V are never materialized.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rotary, dense, init_dense

NEG_INF = -1e30


def init_mla(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    d, nh = cfg.d_model, cfg.num_heads
    r, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    dr, dn, dv = cfg.qk_rope_head_dim, cfg.qk_nope_head_dim, cfg.v_head_dim
    p = {
        "w_dkv": init_dense(ks[0], d, r, dtype=dtype),
        "w_krope": init_dense(ks[1], d, dr, dtype=dtype),
        "w_uk": init_dense(ks[2], r, nh * dn, dtype=dtype),
        "w_uv": init_dense(ks[3], r, nh * dv, dtype=dtype),
        "wo": init_dense(ks[4], nh * dv, d, dtype=dtype),
    }
    q_dim = nh * (dn + dr)
    if qr:
        p["w_dq"] = init_dense(ks[5], d, qr, dtype=dtype)
        p["w_uq"] = init_dense(ks[6], qr, q_dim, dtype=dtype)
    else:
        p["w_q"] = init_dense(ks[5], d, q_dim, dtype=dtype)
    return p


def _queries(p, x, cfg, positions):
    nh = cfg.num_heads
    dr, dn = cfg.qk_rope_head_dim, cfg.qk_nope_head_dim
    if "w_dq" in p:
        q = dense(p["w_uq"], dense(p["w_dq"], x, x.dtype), x.dtype)
    else:
        q = dense(p["w_q"], x, x.dtype)
    q = q.reshape(x.shape[:-1] + (nh, dn + dr))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rotary(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_full(p, x, cfg, positions=None, *, window=0):
    """Training / prefill MLA over a full sequence (naive expansion)."""
    B, S, _ = x.shape
    nh = cfg.num_heads
    dr, dn, dv = cfg.qk_rope_head_dim, cfg.qk_nope_head_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_rope = _queries(p, x, cfg, positions)

    c_kv = dense(p["w_dkv"], x, x.dtype)                       # (B,S,r)
    k_rope = dense(p["w_krope"], x, x.dtype)[..., None, :]     # (B,S,1,dr)
    k_rope = apply_rotary(k_rope, positions, cfg.rope_theta)
    k_nope = dense(p["w_uk"], c_kv, x.dtype).reshape(B, S, nh, dn)
    v = dense(p["w_uv"], c_kv, x.dtype).reshape(B, S, nh, dv)

    scale = (dn + dr) ** -0.5
    scores = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
              + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope[..., 0, :]))
    scores = scores.astype(jnp.float32) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    m = kpos <= qpos
    if window > 0:
        m = m & (kpos > qpos - window)
    scores = jnp.where(m[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    return dense(p["wo"], out.reshape(B, S, nh * dv), x.dtype)


def init_mla_cache(cfg, batch, length, dtype=jnp.bfloat16, layers=None):
    L = cfg.num_layers if layers is None else layers
    return {
        "c_kv": jnp.zeros((L, batch, length, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((L, batch, length, cfg.qk_rope_head_dim), dtype),
    }


def mla_decode(p, x, layer_cache, pos, cfg, *, ring=False):
    """One-token absorbed-MLA decode against the latent cache."""
    B = x.shape[0]
    nh = cfg.num_heads
    dr, dn, dv = cfg.qk_rope_head_dim, cfg.qk_nope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    cd = x.dtype

    q_nope, q_rope = _queries(p, x, cfg, pos[:, None])  # (B,1,nh,·)

    c_new = dense(p["w_dkv"], x, cd)                     # (B,1,r)
    kr_new = dense(p["w_krope"], x, cd)[..., None, :]
    kr_new = apply_rotary(kr_new, pos[:, None], cfg.rope_theta)[..., 0, :]

    ck, kr = layer_cache["c_kv"], layer_cache["k_rope"]
    S = ck.shape[1]
    slot = pos % S if ring else jnp.minimum(pos, S - 1)
    bidx = jnp.arange(B)
    ck = ck.at[bidx, slot].set(c_new[:, 0].astype(ck.dtype))
    kr = kr.at[bidx, slot].set(kr_new[:, 0].astype(kr.dtype))

    # absorb: q_eff[h] = q_nope[h] @ W_uk[h]^T  -> latent space
    w_uk = p["w_uk"]["w"].reshape(r, nh, dn).astype(cd)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)   # (B,1,nh,r)

    scale = (dn + dr) ** -0.5
    scores = (jnp.einsum("bqhr,bkr->bhqk", q_lat, ck.astype(cd))
              + jnp.einsum("bqhd,bkd->bhqk", q_rope, kr.astype(cd)))
    scores = scores.astype(jnp.float32) * scale

    kpos = jnp.arange(S)[None, :]
    n_filled = jnp.minimum(pos + 1, S)[:, None]
    valid = kpos < n_filled if ring else kpos <= pos[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(cd)

    o_lat = jnp.einsum("bhqk,bkr->bqhr", w, ck.astype(cd))  # (B,1,nh,r)
    w_uv = p["w_uv"]["w"].reshape(r, nh, dv).astype(cd)
    out = jnp.einsum("bqhr,rhd->bqhd", o_lat, w_uv)
    out = dense(p["wo"], out.reshape(B, 1, nh * dv), cd)
    return out, {"c_kv": ck, "k_rope": kr}
