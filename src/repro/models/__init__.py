from repro.models.model import (count_params, decode_step, decode_window,
                                forward, init_cache, init_params)

__all__ = ["init_params", "forward", "decode_step", "init_cache",
           "decode_window", "count_params"]
