"""Common neural-net building blocks (pure JAX, params = nested dicts).

Conventions:
  * ``init_<layer>(key, ...) -> params`` and ``<layer>(params, x, ...) -> y``.
  * Params are stored in ``param_dtype`` (fp32 by default); compute runs in
    ``compute_dtype`` (bf16) — matmuls cast inputs, accumulate fp32 where it
    matters (attention softmax, norms, losses).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# -- initializers -----------------------------------------------------------

def normal_init(key, shape, scale=0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def zeros_init(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


# -- dense ------------------------------------------------------------------

def init_dense(key, d_in, d_out, *, bias=False, scale=0.02, dtype=jnp.float32):
    p = {"w": normal_init(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = zeros_init((d_out,), dtype)
    return p


def dense(p, x, compute_dtype=jnp.bfloat16):
    y = jnp.einsum("...i,io->...o", x.astype(compute_dtype),
                   p["w"].astype(compute_dtype))
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


# -- norms --------------------------------------------------------------------

def init_norm(kind, d, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def norm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# -- activations --------------------------------------------------------------

def act_fn(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# -- MLP (SwiGLU for silu, plain 2-layer for gelu) ----------------------------

def init_mlp(key, d_model, d_ff, activation, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"up": init_dense(ks[0], d_model, d_ff, dtype=dtype),
         "down": init_dense(ks[1], d_ff, d_model, dtype=dtype)}
    if activation == "silu":
        p["gate"] = init_dense(ks[2], d_model, d_ff, dtype=dtype)
    return p


def mlp(p, x, activation, compute_dtype=jnp.bfloat16):
    f = act_fn(activation)
    h = dense(p["up"], x, compute_dtype)
    if "gate" in p:
        h = h * f(dense(p["gate"], x, compute_dtype))
    else:
        h = f(h)
    return dense(p["down"], h, compute_dtype)


# -- rotary -------------------------------------------------------------------

def rotary_freqs(head_dim, theta):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rotary(x, positions, theta=10_000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rotary_freqs(hd, theta))  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- embeddings ---------------------------------------------------------------

def init_embedding(key, vocab, d, dtype=jnp.float32):
    return {"table": normal_init(key, (vocab, d), 0.02, dtype)}


def embed(p, tokens, compute_dtype=jnp.bfloat16):
    return p["table"].astype(compute_dtype)[tokens]


def unembed(p, x, compute_dtype=jnp.bfloat16):
    return jnp.einsum("...d,vd->...v", x.astype(compute_dtype),
                      p["table"].astype(compute_dtype))
