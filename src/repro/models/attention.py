"""GQA/MHA attention with KV cache, causal and sliding-window masks.

Three entry points:
  * ``attend_full``   — training / prefill over a whole sequence.
  * ``attend_decode`` — one new token against a filled KV cache.
  * ``init_kv_cache`` — cache pytree (used by the rollout engine and the
    decode-shape dry-runs).

The pure-jnp path is the reference; ``repro.kernels.flash_attention`` and
``repro.kernels.decode_attention`` provide the Pallas TPU implementations
selected via ``use_pallas``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rotary, dense, init_dense

NEG_INF = -1e30


def init_attention(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    nh, nkv, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    return {
        "wq": init_dense(ks[0], d, nh * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_dense(ks[1], d, nkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_dense(ks[2], d, nkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_dense(ks[3], nh * hd, d, dtype=dtype),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def sdpa(q, k, v, mask):
    """q: (B,Sq,H,hd) k/v: (B,Sk,H,hd) mask: broadcastable (B,1,Sq,Sk)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def causal_mask(sq, sk, q_offset=0, window=0):
    """(1,1,sq,sk) causal mask; ``window``>0 adds a sliding-window band."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window > 0:
        m = m & (kpos > qpos - window)
    return m[None, None]


def attend_full(p, x, cfg, positions=None, *, window=0, cross_kv=None,
                causal=True, use_pallas=False):
    """Full-sequence attention (train / prefill / encoder / cross).

    cross_kv: optional (k_src, v_src) already-projected encoder memory for
    cross-attention (no mask).
    """
    B, S, _ = x.shape
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cd = x.dtype
    q = _split_heads(dense(p["wq"], x, cd), nh, hd)
    if cross_kv is None:
        k = _split_heads(dense(p["wk"], x, cd), nkv, hd)
        v = _split_heads(dense(p["wv"], x, cd), nkv, hd)
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q = apply_rotary(q, positions, cfg.rope_theta)
        k = apply_rotary(k, positions, cfg.rope_theta)
    else:
        k, v = cross_kv

    if use_pallas and cross_kv is None and causal:
        from repro.kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, window=window)
    else:
        kk = _repeat_kv(k, nh // k.shape[2])
        vv = _repeat_kv(v, nh // v.shape[2])
        if cross_kv is not None or not causal:
            mask = jnp.ones((1, 1, S, k.shape[1]), bool)
        else:
            mask = causal_mask(S, S, window=window)
        out = sdpa(q, kk, vv, mask)
    return dense(p["wo"], out.reshape(B, S, nh * hd), cd)


def project_cross_kv(p, memory, cfg):
    """Precompute encoder K/V once for all decode steps."""
    nkv, hd = cfg.num_kv_heads, cfg.head_dim
    k = _split_heads(dense(p["wk"], memory, memory.dtype), nkv, hd)
    v = _split_heads(dense(p["wv"], memory, memory.dtype), nkv, hd)
    return k, v


def init_kv_cache(cfg, batch, length, dtype=jnp.bfloat16, layers=None):
    """Stacked-over-layers GQA cache."""
    L = cfg.num_layers if layers is None else layers
    shape = (L, batch, length, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attend_decode(p, x, layer_cache, pos, cfg, *, ring=False, write=True,
                  use_pallas=False, mesh=None):
    """One-token decode.

    x: (B, 1, d); layer_cache: {"k","v"} of (B, S_cache, nkv, hd);
    pos: (B,) current absolute position of the new token.
    ring=True → sliding-window ring buffer (cache slot = pos % S_cache).
    write=False → read-only attention over the full provided cache (used for
    cross-attention with precomputed encoder K/V); no rotary on q either.
    mesh → route attention through distributed/flash_decode's sharded
    partial-softmax combine (cache seq dim sharded over "model").

    Returns (out (B,1,d), updated layer_cache).
    """
    B = x.shape[0]
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cd = x.dtype
    q = _split_heads(dense(p["wq"], x, cd), nh, hd)

    k_cache, v_cache = layer_cache["k"], layer_cache["v"]
    S = k_cache.shape[1]

    if write:
        q = apply_rotary(q, pos[:, None], cfg.rope_theta)
        k_new = _split_heads(dense(p["wk"], x, cd), nkv, hd)
        v_new = _split_heads(dense(p["wv"], x, cd), nkv, hd)
        k_new = apply_rotary(k_new, pos[:, None], cfg.rope_theta)

        slot = pos % S if ring else jnp.minimum(pos, S - 1)
        bidx = jnp.arange(B)
        k_cache = k_cache.at[bidx, slot].set(k_new[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[bidx, slot].set(v_new[:, 0].astype(v_cache.dtype))

        kpos = jnp.arange(S)[None, :]
        n_filled = jnp.minimum(pos + 1, S)[:, None]
        valid = (kpos < n_filled) if ring else (kpos <= pos[:, None])
    else:
        valid = jnp.ones((B, S), bool)
    mask = valid[:, None, None, :]  # (B,1,1,S)

    if mesh is not None:
        from repro.distributed.flash_decode import sharded_decode_attention
        out = sharded_decode_attention(q, k_cache.astype(cd),
                                       v_cache.astype(cd), valid, mesh=mesh)
    elif use_pallas:
        from repro.kernels.decode_attention.ops import decode_attention
        out = decode_attention(q, k_cache.astype(cd), v_cache.astype(cd), valid)
    else:
        kk = _repeat_kv(k_cache.astype(cd), nh // nkv)
        vv = _repeat_kv(v_cache.astype(cd), nh // nkv)
        out = sdpa(q, kk, vv, mask)

    out = dense(p["wo"], out.reshape(B, 1, nh * hd), cd)
    return out, {"k": k_cache, "v": v_cache}
