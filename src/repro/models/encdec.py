"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

``input_specs`` provides precomputed conv/mel frame embeddings
(B, frames, d) — the assignment's carve-out. We implement the transformer
encoder over those frames and the full decoder (self + cross attention),
with learned positions as in Whisper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.layers import (dense, dtype_of, embed, init_dense,
                                 init_embedding, init_mlp, init_norm, mlp,
                                 norm, normal_init, unembed)


def _init_enc_block(key, cfg):
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 2)
    return {"ln1": init_norm(cfg.norm, cfg.d_model, dt),
            "attn": attn.init_attention(ks[0], cfg, dt),
            "ln2": init_norm(cfg.norm, cfg.d_model, dt),
            "ffn": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation, dt)}


def _init_dec_block(key, cfg):
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = _init_enc_block(ks[0], cfg)
    p["ln_x"] = init_norm(cfg.norm, cfg.d_model, dt)
    p["cross"] = attn.init_attention(ks[1], cfg, dt)
    return p


def init_encdec(key, cfg):
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    n_enc, n_dec = cfg.encoder_layers, cfg.num_layers
    return {
        "enc_pos": normal_init(ks[0], (cfg.encoder_frames, cfg.d_model), 0.02, dt),
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg))(
            jax.random.split(ks[1], n_enc)),
        "enc_norm": init_norm(cfg.norm, cfg.d_model, dt),
        "embed": init_embedding(ks[2], cfg.vocab_size, cfg.d_model, dt),
        "dec_pos": normal_init(ks[3], (cfg.max_target_positions, cfg.d_model),
                               0.02, dt),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg))(
            jax.random.split(ks[4], n_dec)),
        "final_norm": init_norm(cfg.norm, cfg.d_model, dt),
    }


def encode(params, cfg, frames):
    """frames: (B, F, d) stubbed frontend embeddings -> (B, F, d) memory."""
    cd = dtype_of(cfg.compute_dtype)
    x = frames.astype(cd) + params["enc_pos"][None, :frames.shape[1]].astype(cd)

    def body(h, blk):
        y = attn.attend_full(blk["attn"], norm(blk["ln1"], h), cfg, causal=False)
        h = h + y
        h = h + mlp(blk["ffn"], norm(blk["ln2"], h), cfg.activation, cd)
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return norm(params["enc_norm"], x)


def decode_train(params, cfg, memory, tokens, positions=None):
    """Teacher-forced decoder forward. Returns (logits, 0.0, None)."""
    cd = dtype_of(cfg.compute_dtype)
    B, S = tokens.shape
    pos_tab = params["dec_pos"]
    idx = jnp.arange(S) % pos_tab.shape[0]
    x = embed(params["embed"], tokens, cd) + pos_tab[idx][None].astype(cd)

    def body(h, blk):
        y = attn.attend_full(blk["attn"], norm(blk["ln1"], h), cfg)
        h = h + y
        kv = attn.project_cross_kv(blk["cross"], memory, cfg)
        y = attn.attend_full(blk["cross"], norm(blk["ln_x"], h), cfg,
                             cross_kv=kv)
        h = h + y
        h = h + mlp(blk["ffn"], norm(blk["ln2"], h), cfg.activation, cd)
        return h, None

    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = norm(params["final_norm"], x)
    return unembed(params["embed"], x, cd), 0.0, None


def init_dec_cache(cfg, batch, length, dtype=jnp.bfloat16):
    """Self-attn KV cache + precomputed cross K/V slots."""
    return {
        "self": attn.init_kv_cache(cfg, batch, length, dtype),
        "cross_k": jnp.zeros((cfg.num_layers, batch, cfg.encoder_frames,
                              cfg.num_kv_heads, cfg.head_dim), dtype),
        "cross_v": jnp.zeros((cfg.num_layers, batch, cfg.encoder_frames,
                              cfg.num_kv_heads, cfg.head_dim), dtype),
    }


def precompute_cross_kv(params, cfg, memory, cache):
    """Fill the cross K/V slots once after encoding."""
    def per_layer(blk):
        k, v = attn.project_cross_kv(blk["cross"], memory, cfg)
        return k, v
    ks, vs = jax.lax.map(per_layer, params["dec_blocks"])
    return {**cache, "cross_k": ks.astype(cache["cross_k"].dtype),
            "cross_v": vs.astype(cache["cross_v"].dtype)}


def decode_step(params, cfg, cache, token, pos):
    """One decoder token. Returns (logits (B,V), new cache)."""
    cd = dtype_of(cfg.compute_dtype)
    B = token.shape[0]
    pos_tab = params["dec_pos"]
    pidx = pos % pos_tab.shape[0]
    x = embed(params["embed"], token[:, None], cd) + pos_tab[pidx][:, None].astype(cd)

    def body(h, xs):
        blk, lc, ck, cv = xs
        y, nc = attn.attend_decode(blk["attn"], norm(blk["ln1"], h), lc, pos, cfg)
        h = h + y
        y, _ = attn.attend_decode(
            blk["cross"], norm(blk["ln_x"], h),
            {"k": ck, "v": cv}, jnp.full_like(pos, ck.shape[1] - 1), cfg,
            write=False)
        h = h + y
        h = h + mlp(blk["ffn"], norm(blk["ln2"], h), cfg.activation, cd)
        return h, nc

    x, new_self = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["self"],
                  cache["cross_k"], cache["cross_v"]))
    x = norm(params["final_norm"], x)
    logits = unembed(params["embed"], x, cd)
    return logits[:, 0], {**cache, "self": new_self}
