"""Mamba-1 selective-scan SSM block (Falcon-Mamba).

Full-sequence path uses an associative scan over time (the Pallas
``mamba_scan`` kernel is the TPU-optimized version); decode keeps an
O(1)-size recurrent state ``(h, conv window)`` — this is why the ssm arch
runs long_500k natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense


def init_mamba(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr, kc = cfg.ssm_dt_rank, cfg.ssm_conv
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": init_dense(ks[0], d, 2 * di, dtype=dtype),
        "conv_w": (0.1 * jax.random.normal(ks[1], (kc, di))).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": init_dense(ks[2], di, dtr + 2 * ds, dtype=dtype),
        "dt_proj": {"w": (0.1 * jax.random.normal(ks[3], (dtr, di))).astype(dtype),
                    "b": jnp.full((di,), -4.6, dtype)},  # softplus^-1(0.01)
        "a_log": jnp.log(a).astype(dtype),
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": init_dense(ks[4], di, d, dtype=dtype),
    }


def _ssm_params(p, x_inner, cfg, cd):
    """Per-timestep dt, B, C from x_inner (..., di)."""
    ds, dtr = cfg.ssm_state, cfg.ssm_dt_rank
    dbc = dense(p["x_proj"], x_inner, cd)
    dt_r, b, c = jnp.split(dbc, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("...r,rd->...d", dt_r.astype(jnp.float32),
                   p["dt_proj"]["w"].astype(jnp.float32))
        + p["dt_proj"]["b"].astype(jnp.float32))            # (..., di)
    return dt, b.astype(jnp.float32), c.astype(jnp.float32)


def _causal_conv(p, x, cfg):
    """Depthwise causal conv over seq. x: (B,S,di)."""
    kc = cfg.ssm_conv
    xpad = jnp.pad(x, ((0, 0), (kc - 1, 0), (0, 0)))
    w = p["conv_w"].astype(x.dtype)                          # (kc, di)
    out = sum(xpad[:, i:i + x.shape[1], :] * w[i] for i in range(kc))
    return out + p["conv_b"].astype(x.dtype)


def mamba_full(p, x, cfg, use_pallas=False, chunk: int = 0):
    """x: (B,S,d) -> (B,S,d).

    chunk > 0 enables the chunked scan (the Pallas kernel's TPU algorithm
    in pure JAX): a sequential lax.scan over S/chunk blocks with a
    log-depth associative scan inside each block. Peak intermediate memory
    drops from O(B·S·di·ds) to O(B·chunk·di·ds) — the §Perf fix for the
    train_4k memory blow-up on SSM archs.
    """
    B, S, _ = x.shape
    di, ds = cfg.d_inner, cfg.ssm_state
    cd = x.dtype
    xz = dense(p["in_proj"], x, cd)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = jax.nn.silu(_causal_conv(p, x_in, cfg))

    dt, b, c = _ssm_params(p, x_in, cfg, cd)                 # (B,S,di),(B,S,ds)x2
    a = -jnp.exp(p["a_log"].astype(jnp.float32))             # (di,ds)

    def comb(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, br + ar * bl

    if use_pallas:
        from repro.kernels.mamba_scan.ops import mamba_scan
        y = mamba_scan(x_in.astype(jnp.float32), dt, a, b, c)
    elif chunk and S % chunk == 0 and S > chunk:
        nc = S // chunk
        xf = x_in.astype(jnp.float32)

        @jax.checkpoint  # backward recomputes the (B,C,di,ds) tensors —
        def body(h_carry, inp):  # peak memory is ONE chunk, not the full S
            xt, dtt, bt, ct = inp                            # (B,C,·)
            da = jnp.exp(dtt[..., None] * a)                 # (B,C,di,ds)
            dbx = (dtt * xt)[..., None] * bt[:, :, None, :]
            prod, s = jax.lax.associative_scan(comb, (da, dbx), axis=1)
            h = s + prod * h_carry[:, None]
            yt = jnp.einsum("bcdn,bcn->bcd", h, ct)
            return h[:, -1], yt

        resh = lambda t: jnp.moveaxis(
            t.reshape(B, nc, chunk, *t.shape[2:]), 1, 0)
        h0 = jnp.zeros((B, di, ds), jnp.float32)
        _, ys = jax.lax.scan(body, h0,
                             (resh(xf), resh(dt), resh(b), resh(c)))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)
    else:
        # associative scan: h_t = da_t * h_{t-1} + dbx_t
        da = jnp.exp(dt[..., None] * a)                      # (B,S,di,ds)
        dbx = (dt * x_in.astype(jnp.float32))[..., None] * b[:, :, None, :]
        _, h = jax.lax.associative_scan(comb, (da, dbx), axis=1)
        y = jnp.einsum("bsdn,bsn->bsd", h, c)
    y = y + x_in.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y.astype(cd) * jax.nn.silu(z)
    return dense(p["out_proj"], y, cd)


def init_mamba_cache(cfg, batch, dtype=jnp.float32, layers=None):
    L = cfg.num_layers if layers is None else layers
    di, ds, kc = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {"h": jnp.zeros((L, batch, di, ds), dtype),
            "conv": jnp.zeros((L, batch, kc - 1, di), dtype)}


def mamba_decode(p, x, layer_cache, cfg):
    """One-step recurrence. x: (B,1,d)."""
    B = x.shape[0]
    cd = x.dtype
    kc = cfg.ssm_conv
    xz = dense(p["in_proj"], x, cd)
    x_in, z = jnp.split(xz, 2, axis=-1)                      # (B,1,di)

    conv_buf = layer_cache["conv"]                           # (B,kc-1,di)
    window = jnp.concatenate([conv_buf, x_in.astype(conv_buf.dtype)], axis=1)
    w = p["conv_w"].astype(cd)
    x_c = jnp.einsum("bkd,kd->bd", window.astype(cd), w) + p["conv_b"].astype(cd)
    x_c = jax.nn.silu(x_c)[:, None, :]                       # (B,1,di)
    new_conv = window[:, 1:, :]

    dt, b, c = _ssm_params(p, x_c, cfg, cd)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt[:, 0, :, None] * a)                      # (B,di,ds)
    dbx = (dt[:, 0] * x_c[:, 0].astype(jnp.float32))[..., None] * b[:, 0, None, :]
    h = da * layer_cache["h"] + dbx                          # (B,di,ds)
    y = jnp.einsum("bdn,bn->bd", h, c[:, 0])
    y = y + x_c[:, 0].astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = (y[:, None, :].astype(cd)) * jax.nn.silu(z)
    out = dense(p["out_proj"], y, cd)
    return out, {"h": h, "conv": new_conv}
