"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The block wraps the RG-LRU with an input projection producing (x, gate z),
a short causal temporal conv on the x branch, and an output projection
gated by gelu(z) — per the Griffin recurrent block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense

_C = 8.0  # Griffin's recurrence sharpness constant


def init_rglru_block(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    d, w = cfg.d_model, cfg.rnn_width
    # Lambda init so that a in [0.9, 0.999] at r=1 (Griffin appendix)
    u = jax.random.uniform(ks[3], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log(u)/c)
    return {
        "in_x": init_dense(ks[0], d, w, dtype=dtype),
        "in_z": init_dense(ks[1], d, w, dtype=dtype),
        "conv_w": (0.1 * jax.random.normal(ks[2], (4, w))).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "gate_a": init_dense(ks[4], w, w, dtype=dtype),
        "gate_x": init_dense(jax.random.fold_in(key, 7), w, w, dtype=dtype),
        "lambda": lam.astype(dtype),
        "out": init_dense(jax.random.fold_in(key, 9), w, d, dtype=dtype),
    }


def _gates(p, xc, cd):
    r = jax.nn.sigmoid(dense(p["gate_a"], xc, cd).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["gate_x"], xc, cd).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * i * xc.astype(jnp.float32)


def _conv(p, x, decode_buf=None):
    """Causal temporal conv, kernel 4. x: (B,S,w)."""
    k = p["conv_w"].shape[0]
    if decode_buf is None:
        xpad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        out = sum(xpad[:, i:i + x.shape[1], :] * p["conv_w"][i].astype(x.dtype)
                  for i in range(k))
        return out + p["conv_b"].astype(x.dtype), None
    window = jnp.concatenate([decode_buf, x.astype(decode_buf.dtype)], axis=1)
    out = jnp.einsum("bkd,kd->bd", window.astype(x.dtype),
                     p["conv_w"].astype(x.dtype))[:, None, :]
    return out + p["conv_b"].astype(x.dtype), window[:, 1:, :]


def rglru_full(p, x, cfg, use_pallas=False):
    """x: (B,S,d) -> (B,S,d)."""
    cd = x.dtype
    xb = dense(p["in_x"], x, cd)
    z = dense(p["in_z"], x, cd)
    xc, _ = _conv(p, xb)
    a, bx = _gates(p, xc, cd)

    if use_pallas:
        from repro.kernels.rglru_scan.ops import rglru_scan
        h = rglru_scan(a, bx)
    else:
        def comb(l, r):
            (al, hl), (ar, hr) = l, r
            return al * ar, hr + ar * hl
        _, h = jax.lax.associative_scan(comb, (a, bx), axis=1)
    y = h.astype(cd) * jax.nn.gelu(z)
    return dense(p["out"], y, cd)


def init_rglru_cache(cfg, batch, n_layers, dtype=jnp.float32):
    w = cfg.rnn_width
    return {"h": jnp.zeros((n_layers, batch, w), dtype),
            "conv": jnp.zeros((n_layers, batch, 3, w), dtype)}


def rglru_decode(p, x, layer_cache, cfg):
    """One-step. x: (B,1,d)."""
    cd = x.dtype
    xb = dense(p["in_x"], x, cd)
    z = dense(p["in_z"], x, cd)
    xc, new_conv = _conv(p, xb, decode_buf=layer_cache["conv"])
    a, bx = _gates(p, xc, cd)                                # (B,1,w)
    h = a[:, 0] * layer_cache["h"] + bx[:, 0]
    y = h[:, None, :].astype(cd) * jax.nn.gelu(z)
    out = dense(p["out"], y, cd)
    return out, {"h": h, "conv": new_conv}
