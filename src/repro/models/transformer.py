"""Decoder-only LM assembly for dense / moe / ssm / hybrid / vlm families.

Layer stacks are homogeneous pytrees with a leading layer axis, iterated
with ``jax.lax.scan`` — this keeps the HLO (and dry-run compile time) small
for 60+-layer models. The hybrid (Griffin) family scans over
(recurrent, recurrent, attention) tiles.

Every forward returns ``(logits, aux)`` where aux carries the MoE
load-balance loss (0 otherwise).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (dtype_of, embed, init_embedding, init_mlp,
                                 init_norm, mlp, norm, unembed, init_dense,
                                 dense)


# ---------------------------------------------------------------------------
# Block init/apply
# ---------------------------------------------------------------------------


def _init_attn_block(key, cfg, ffn_kind):
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg.param_dtype)
    if cfg.attention == "mla":
        a = mla_mod.init_mla(ks[0], cfg, dt)
    else:
        a = attn.init_attention(ks[0], cfg, dt)
    p = {"ln1": init_norm(cfg.norm, cfg.d_model, dt), "attn": a,
         "ln2": init_norm(cfg.norm, cfg.d_model, dt)}
    if ffn_kind == "moe":
        p["ffn"] = moe_mod.init_moe(ks[1], cfg, dt)
    else:
        p["ffn"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation, dt)
    return p


def _attn_block_full(p, x, cfg, ffn_kind, *, window, use_pallas, positions,
                     return_kv):
    h = norm(p["ln1"], x)
    if cfg.attention == "mla":
        y = mla_mod.mla_full(p["attn"], h, cfg, positions, window=window)
        kv = None
        if return_kv:
            cd = x.dtype
            c_kv = dense(p["attn"]["w_dkv"], h, cd)
            k_rope = dense(p["attn"]["w_krope"], h, cd)[..., None, :]
            from repro.models.layers import apply_rotary
            pos = positions if positions is not None else jnp.arange(x.shape[1])[None, :]
            k_rope = apply_rotary(k_rope, pos, cfg.rope_theta)[..., 0, :]
            kv = {"c_kv": c_kv, "k_rope": k_rope}
    else:
        y = attn.attend_full(p["attn"], h, cfg, positions, window=window,
                             use_pallas=use_pallas)
        kv = None
        if return_kv:
            k, v = attn.project_cross_kv(p["attn"], h, cfg)
            from repro.models.layers import apply_rotary
            pos = positions if positions is not None else jnp.arange(x.shape[1])[None, :]
            k = apply_rotary(k, pos, cfg.rope_theta)
            kv = {"k": k, "v": v}
    x = x + y
    h = norm(p["ln2"], x)
    if ffn_kind == "moe":
        y, aux = moe_mod.moe_ffn(p["ffn"], h, cfg)
    else:
        y, aux = mlp(p["ffn"], h, cfg.activation, x.dtype), 0.0
    return x + y, aux, kv


def _attn_block_decode(p, x, layer_cache, pos, cfg, ffn_kind, *, ring,
                       use_pallas=False, mesh=None):
    h = norm(p["ln1"], x)
    if cfg.attention == "mla":
        y, new_cache = mla_mod.mla_decode(p["attn"], h, layer_cache, pos, cfg,
                                          ring=ring)
    else:
        y, new_cache = attn.attend_decode(p["attn"], h, layer_cache, pos, cfg,
                                          ring=ring, use_pallas=use_pallas,
                                          mesh=mesh)
    x = x + y
    h = norm(p["ln2"], x)
    if ffn_kind == "moe":
        y, _ = moe_mod.moe_ffn(p["ffn"], h, cfg)
    else:
        y = mlp(p["ffn"], h, cfg.activation, x.dtype)
    return x + y, new_cache


def _init_mamba_block(key, cfg):
    dt = dtype_of(cfg.param_dtype)
    return {"ln": init_norm(cfg.norm, cfg.d_model, dt),
            "mamba": ssm_mod.init_mamba(key, cfg, dt)}


def _init_rec_block(key, cfg):
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 2)
    return {"ln1": init_norm(cfg.norm, cfg.d_model, dt),
            "rec": rglru_mod.init_rglru_block(ks[0], cfg, dt),
            "ln2": init_norm(cfg.norm, cfg.d_model, dt),
            "ffn": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation, dt)}


def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------


def init_lm(key, cfg):
    """Parameters for any decoder-only family in the zoo."""
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    params = {"embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, dt),
              "final_norm": init_norm(cfg.norm, cfg.d_model, dt)}
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(ks[1], cfg.d_model, cfg.vocab_size, dtype=dt)

    L = cfg.num_layers
    if cfg.arch_type == "ssm":
        params["blocks"] = _stack_init(lambda k: _init_mamba_block(k, cfg), ks[2], L)
    elif cfg.arch_type == "hybrid":
        pat = cfg.rglru_block_pattern
        n_tiles, rem = divmod(L, len(pat))
        if n_tiles:
            tile = {}
            for i, kind in enumerate(pat):
                fn = (lambda k: _init_rec_block(k, cfg)) if kind == "recurrent" \
                    else (lambda k: _init_attn_block(k, cfg, "dense"))
                tile[f"{i}_{kind}"] = _stack_init(
                    fn, jax.random.fold_in(ks[2], i), n_tiles)
            params["tiles"] = tile
        if rem:
            rem_blocks = []
            for i in range(rem):
                kind = pat[i]
                fn = (lambda k: _init_rec_block(k, cfg)) if kind == "recurrent" \
                    else (lambda k: _init_attn_block(k, cfg, "dense"))
                rem_blocks.append(fn(jax.random.fold_in(ks[3], i)))
            params["rem"] = rem_blocks
    elif cfg.arch_type == "moe":
        nd = cfg.first_dense_layers
        if nd:
            params["dense_blocks"] = _stack_init(
                lambda k: _init_attn_block(k, cfg, "dense"), ks[3], nd)
        params["blocks"] = _stack_init(
            lambda k: _init_attn_block(k, cfg, "moe"), ks[2], L - nd)
    else:  # dense / vlm
        params["blocks"] = _stack_init(
            lambda k: _init_attn_block(k, cfg, "dense"), ks[2], L)
    return params


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def forward_lm(params, cfg, tokens, *, extra_embeds=None, window=0,
               use_pallas=False, return_cache=False, positions=None):
    """tokens: (B, S) int32. extra_embeds: (B, T, d) prepended (VLM/audio
    stubs). Returns (logits (B, S_total, V), aux, cache_or_None)."""
    cd = dtype_of(cfg.compute_dtype)
    x = embed(params["embed"], tokens, cd)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cd), x], axis=1)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    ffn_kind_main = "moe" if cfg.arch_type == "moe" else "dense"
    aux_total = 0.0
    cache = {}

    if cfg.arch_type == "ssm":
        def body(carry, blk):
            h, = carry
            y = ssm_mod.mamba_full(blk["mamba"], norm(blk["ln"], h), cfg,
                                   use_pallas=use_pallas,
                                   chunk=cfg.ssm_chunk)
            return (h + y,), None
        (x,), _ = jax.lax.scan(body, (x,), params["blocks"])

    elif cfg.arch_type == "hybrid":
        pat = cfg.rglru_block_pattern
        def tile_body(carry, tile_params):
            h, = carry
            kvs = {}
            for i, kind in enumerate(pat):
                p = tile_params[f"{i}_{kind}"]
                if kind == "recurrent":
                    y = rglru_mod.rglru_full(p["rec"], norm(p["ln1"], h), cfg,
                                             use_pallas=use_pallas)
                    h = h + y
                    h = h + mlp(p["ffn"], norm(p["ln2"], h), cfg.activation, cd)
                else:
                    h, _, kv = _attn_block_full(
                        p, h, cfg, "dense", window=cfg.local_window,
                        use_pallas=use_pallas, positions=positions,
                        return_kv=return_cache)
                    if return_cache:
                        kvs = kv
            return (h,), kvs if return_cache else None
        tile_kvs = None
        if "tiles" in params:
            (x,), tile_kvs = jax.lax.scan(tile_body, (x,), params["tiles"])
        for p in params.get("rem", []):
            if "rec" in p:
                y = rglru_mod.rglru_full(p["rec"], norm(p["ln1"], x), cfg,
                                         use_pallas=use_pallas)
                x = x + y
                x = x + mlp(p["ffn"], norm(p["ln2"], x), cfg.activation, cd)
            else:
                x, _, _ = _attn_block_full(p, x, cfg, "dense",
                                           window=cfg.local_window,
                                           use_pallas=use_pallas,
                                           positions=positions, return_kv=False)
        if return_cache:
            cache["att_kv"] = tile_kvs

    else:  # dense / moe / vlm
        def body(carry, blk):
            h, aux = carry
            h, a, kv = _attn_block_full(blk, h, cfg, ffn_kind_main,
                                        window=window, use_pallas=use_pallas,
                                        positions=positions,
                                        return_kv=return_cache)
            return (h, aux + a), kv if return_cache else None

        if "dense_blocks" in params:
            def dbody(carry, blk):
                h, aux = carry
                h, a, kv = _attn_block_full(blk, h, cfg, "dense", window=window,
                                            use_pallas=use_pallas,
                                            positions=positions,
                                            return_kv=return_cache)
                return (h, aux + a), kv if return_cache else None
            (x, aux_total), kv_d = jax.lax.scan(dbody, (x, 0.0),
                                                params["dense_blocks"])
            if return_cache:
                cache["dense_kv"] = kv_d
        (x, aux_total), kv_m = jax.lax.scan(body, (x, aux_total),
                                            params["blocks"])
        if return_cache:
            cache["kv"] = kv_m

    x = norm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x, cd)
    else:
        logits = dense(params["lm_head"], x, cd)
    return logits, aux_total, (cache if return_cache else None)


def forward_hidden(params, cfg, tokens, **kw):
    """Final-norm hidden states (B, S, d) — used by the PPO value head."""
    return _forward_trunk(params, cfg, tokens, **kw)


def _forward_trunk(params, cfg, tokens, *, extra_embeds=None, window=0,
                   positions=None):
    """The forward_lm body up to (and including) final_norm."""
    cd = dtype_of(cfg.compute_dtype)
    x = embed(params["embed"], tokens, cd)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cd), x], axis=1)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    if cfg.arch_type == "ssm":
        def body(carry, blk):
            h, = carry
            y = ssm_mod.mamba_full(blk["mamba"], norm(blk["ln"], h), cfg)
            return (h + y,), None
        (x,), _ = jax.lax.scan(body, (x,), params["blocks"])
    elif cfg.arch_type == "hybrid":
        pat = cfg.rglru_block_pattern
        def tile_body(carry, tile_params):
            h, = carry
            for i, kind in enumerate(pat):
                p = tile_params[f"{i}_{kind}"]
                if kind == "recurrent":
                    y = rglru_mod.rglru_full(p["rec"], norm(p["ln1"], h), cfg)
                    h = h + y
                    h = h + mlp(p["ffn"], norm(p["ln2"], h), cfg.activation, cd)
                else:
                    h, _, _ = _attn_block_full(
                        p, h, cfg, "dense", window=cfg.local_window,
                        use_pallas=False, positions=positions,
                        return_kv=False)
            return (h,), None
        if "tiles" in params:
            (x,), _ = jax.lax.scan(tile_body, (x,), params["tiles"])
        for p in params.get("rem", []):
            if "rec" in p:
                y = rglru_mod.rglru_full(p["rec"], norm(p["ln1"], x), cfg)
                x = x + y
                x = x + mlp(p["ffn"], norm(p["ln2"], x), cfg.activation, cd)
            else:
                x, _, _ = _attn_block_full(p, x, cfg, "dense",
                                           window=cfg.local_window,
                                           use_pallas=False,
                                           positions=positions,
                                           return_kv=False)
    else:
        ffn_kind = "moe" if cfg.arch_type == "moe" else "dense"
        def body(carry, blk):
            h, = carry
            h, _, _ = _attn_block_full(blk, h, cfg, ffn_kind, window=window,
                                       use_pallas=False, positions=positions,
                                       return_kv=False)
            return (h,), None
        if "dense_blocks" in params:
            def dbody(carry, blk):
                h, = carry
                h, _, _ = _attn_block_full(blk, h, cfg, "dense", window=window,
                                           use_pallas=False,
                                           positions=positions,
                                           return_kv=False)
                return (h,), None
            (x,), _ = jax.lax.scan(dbody, (x,), params["dense_blocks"])
        (x,), _ = jax.lax.scan(body, (x,), params["blocks"])
    return norm(params["final_norm"], x)


# ---------------------------------------------------------------------------
# Decode (one token vs cache)
# ---------------------------------------------------------------------------


def init_cache(cfg, batch, length, dtype=jnp.bfloat16):
    """Cache pytree for decode shapes; ``length`` = KV window actually kept."""
    if cfg.arch_type == "ssm":
        return ssm_mod.init_mamba_cache(cfg, batch, layers=cfg.num_layers)
    if cfg.arch_type == "hybrid":
        pat = cfg.rglru_block_pattern
        n_tiles, rem = divmod(cfg.num_layers, len(pat))
        n_att = sum(1 for k in pat if k == "attention") * n_tiles \
            + sum(1 for k in pat[:rem] if k == "attention")
        n_rec = cfg.num_layers - n_att
        att_len = min(length, cfg.local_window)
        return {
            "rec": rglru_mod.init_rglru_cache(cfg, batch, n_rec),
            "att": attn.init_kv_cache(cfg, batch, att_len, dtype, layers=n_att),
        }
    if cfg.attention == "mla":
        return mla_mod.init_mla_cache(cfg, batch, length, dtype)
    if cfg.arch_type == "moe" and cfg.first_dense_layers:
        return attn.init_kv_cache(cfg, batch, length, dtype)
    return attn.init_kv_cache(cfg, batch, length, dtype)


def decode_lm(params, cfg, cache, token, pos, *, ring=False,
              use_pallas=False, mesh=None):
    """token: (B,) int32; pos: (B,) absolute positions.
    Returns (logits (B, V), new_cache). use_pallas routes attention
    through kernels/decode_attention; mesh through the sharded
    flash-decode combine (dense/moe GQA paths only)."""
    cd = dtype_of(cfg.compute_dtype)
    x = embed(params["embed"], token[:, None], cd)  # (B,1,d)

    if cfg.arch_type == "ssm":
        def body(h, blk_and_cache):
            blk, lc = blk_and_cache
            y, nc = ssm_mod.mamba_decode(blk["mamba"], norm(blk["ln"], h), lc, cfg)
            return h + y, nc
        x, new_cache = jax.lax.scan(
            body, x, (params["blocks"],
                      {"h": cache["h"], "conv": cache["conv"]}))

    elif cfg.arch_type == "hybrid":
        pat = cfg.rglru_block_pattern
        n_tiles, rem = divmod(cfg.num_layers, len(pat))
        rec_per_tile = sum(1 for k in pat if k == "recurrent")
        att_per_tile = len(pat) - rec_per_tile
        rec_c, att_c = cache["rec"], cache["att"]
        n_rec_tiles = n_tiles * rec_per_tile
        # split tile-region caches from remainder-region caches
        rc_t = jax.tree.map(lambda a: a[:n_rec_tiles].reshape(
            (n_tiles, rec_per_tile) + a.shape[1:]), rec_c)
        ac_t = jax.tree.map(lambda a: a[:n_tiles * att_per_tile].reshape(
            (n_tiles, att_per_tile) + a.shape[1:]), att_c)

        def tile_body(carry, xs):
            h, = carry
            tp, rc, ac = xs
            new_rc, new_ac = [], []
            ri, ai = 0, 0
            for i, kind in enumerate(pat):
                p = tp[f"{i}_{kind}"]
                if kind == "recurrent":
                    lc = jax.tree.map(lambda a: a[ri], rc)
                    y, nc = rglru_mod.rglru_decode(p["rec"], norm(p["ln1"], h),
                                                   lc, cfg)
                    h = h + y
                    h = h + mlp(p["ffn"], norm(p["ln2"], h), cfg.activation, cd)
                    new_rc.append(nc)
                    ri += 1
                else:
                    lc = jax.tree.map(lambda a: a[ai], ac)
                    h, nc = _attn_block_decode(p, h, lc, pos, cfg, "dense",
                                               ring=True)
                    new_ac.append(nc)
                    ai += 1
            stack = lambda cs: jax.tree.map(lambda *a: jnp.stack(a), *cs)
            return (h,), (stack(new_rc), stack(new_ac))

        if "tiles" in params:
            (x,), (rc_new, ac_new) = jax.lax.scan(
                tile_body, (x,), (params["tiles"], rc_t, ac_t))
            rc_new = jax.tree.map(
                lambda a: a.reshape((n_rec_tiles,) + a.shape[2:]), rc_new)
            ac_new = jax.tree.map(
                lambda a: a.reshape((n_tiles * att_per_tile,) + a.shape[2:]),
                ac_new)
        else:
            rc_new = jax.tree.map(lambda a: a[:0], rec_c)
            ac_new = att_c
        ri = n_rec_tiles
        rem_rc = []
        for i in range(rem):
            p = params["rem"][i]
            lc = jax.tree.map(lambda a: a[ri + i], rec_c)
            y, nc = rglru_mod.rglru_decode(p["rec"], norm(p["ln1"], x), lc, cfg)
            x = x + y
            x = x + mlp(p["ffn"], norm(p["ln2"], x), cfg.activation, cd)
            rem_rc.append(nc)
        if rem_rc:
            rem_stacked = jax.tree.map(lambda *a: jnp.stack(a), *rem_rc)
            rc_new = jax.tree.map(lambda a, b: jnp.concatenate([a, b]),
                                  rc_new, rem_stacked)
        new_cache = {"rec": rc_new, "att": ac_new}

    else:  # dense / moe / vlm / mla
        ffn_kind = "moe" if cfg.arch_type == "moe" else "dense"
        nd = cfg.first_dense_layers if cfg.arch_type == "moe" else 0
        full_cache = cache

        def split(c, lo, hi):
            return jax.tree.map(lambda a: a[lo:hi], c)

        def body_factory(kind):
            def body(h, xs):
                blk, lc = xs
                h, nc = _attn_block_decode(blk, h, lc, pos, cfg, kind,
                                           ring=ring, use_pallas=use_pallas,
                                           mesh=mesh)
                return h, nc
            return body

        L = cfg.num_layers
        if nd:
            x, c_dense = jax.lax.scan(body_factory("dense"), x,
                                      (params["dense_blocks"],
                                       split(full_cache, 0, nd)))
            x, c_moe = jax.lax.scan(body_factory(ffn_kind), x,
                                    (params["blocks"],
                                     split(full_cache, nd, L)))
            new_cache = jax.tree.map(lambda a, b: jnp.concatenate([a, b]),
                                     c_dense, c_moe)
        else:
            x, new_cache = jax.lax.scan(body_factory(ffn_kind), x,
                                        (params["blocks"], full_cache))

    x = norm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x, cd)
    else:
        logits = dense(params["lm_head"], x, cd)
    return logits[:, 0], new_cache
